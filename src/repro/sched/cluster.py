"""Multi-device runtime: one preemptive executor per accelerator, behind
a placement-aware admission gate (DESIGN.md §7).

``ClusterExecutor`` mirrors the simulator's one-policy-per-device
structure on the live side: it owns one :class:`DeviceExecutor` (and one
``SchedulingPolicy`` instance, resolved per device from the
`core/policy` registry) for every device of an N-device platform, and an
:class:`AdmissionController` configured for that platform as the
cluster-wide gatekeeper — the PR 2 cross-device busy-wait fixed point
(`core/crossfix.py`) finally feeds a real multi-executor runtime.

The placement layer decides *where* an arriving workload runs:

  * ``pinned``       — honor ``JobProfile.device`` verbatim;
  * ``round_robin``  — rotate over devices, next-free-first;
  * ``least_loaded`` — try devices in increasing admitted-GPU-utilization
    order.

Every candidate placement is re-tested by the cross-device admission
analysis *before* committing (``try_admit`` on the profile rebound to
the candidate device), and admit→place→bind happens in one transaction
under the cluster lock: a job only ever exists bound to the device its
admission was proven on.  The binding is immutable — the migration-free
invariant — so the per-device RTAs' assumption that a task's device
segments all execute on ``task.device`` holds by construction, and
``assert_migration_free()`` re-verifies it from the executor traces.

Fault containment (DESIGN.md §10) layers on top without weakening any
of the above:

  * a :class:`~repro.sched.fault.HealthConfig` attaches a slice-level
    heartbeat (:class:`~repro.sched.fault.DeviceHealth`) to every
    executor and a monitor thread that walks the stall → suspect →
    failed ladder;
  * ``fail_device`` opens a new **binding epoch**: the failed device's
    jobs are evicted (orderly, via :class:`DeviceFailedError` at their
    next preemption point), every surviving job's admission is
    re-derived and re-journaled in the new epoch, and the displaced
    jobs are re-run through ``try_admit_many`` against the survivors —
    re-bound with fresh WCRT evidence or explicitly refused, never
    silently dropped.  Bindings stay immutable *within* an epoch (a
    re-bound job is a new ``RTJob`` with a new uid, so the traces still
    prove migration-freedom);
  * a :class:`~repro.sched.elastic.ShedPolicy` arms the overload
    degradation ladder: when an admission pushes a device's total
    (RT + best-effort) utilization past ``shed_at``, best-effort jobs
    are shed (journaled, resumable) before the device is oversubscribed;
    shed jobs resume hysteretically as ``release`` frees capacity.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, Union

from ..core.policy import LEGACY_MODES
from .admission import (AdmissionController, AdmissionDecision, JobProfile,
                        nearest_rank)
from .elastic import (ShedPolicy, can_resume, plan_shedding,
                      profile_utilization, tier_of, tier_utilization)
from .executor import DeviceExecutor, ExecutorTrace
from .fault import FAILED, DeviceHealth, HealthConfig
from .job import BEST_EFFORT, RTJob

if TYPE_CHECKING:  # pragma: no cover
    from .store import JobStore

PLACEMENTS = ("pinned", "round_robin", "least_loaded")


class ClusterExecutor:
    """N preemptive device executors + placement-aware admission.

    ``policy`` is a registry name applied to every device, or a
    per-device sequence of names (one policy instance is built per
    device either way).  ``admission`` overrides the internally built
    :class:`AdmissionController` (required when per-device approaches
    are heterogeneous, since one RTA must price the whole platform).
    ``trace=True`` attaches an :class:`ExecutorTrace` to every executor
    (the conformance harness's input)."""

    def __init__(self, n_devices: int,
                 policy: Union[str, Sequence[str]] = "ioctl",
                 wait_mode: str = "suspend",
                 poll_interval: float = 0.001,
                 n_cpus: int = 4, epsilon_ms: float = 1.0,
                 placement: str = "pinned",
                 try_gpu_priorities: bool = True,
                 trace: bool = False,
                 admission: Optional[AdmissionController] = None,
                 store: Optional["JobStore"] = None,
                 health: Optional[HealthConfig] = None,
                 shed_policy: Optional[ShedPolicy] = None):
        if n_devices < 1:
            raise ValueError("a cluster needs at least one device")
        if placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r} "
                             f"(available: {PLACEMENTS})")
        names = ([policy] * n_devices if isinstance(policy, str)
                 else list(policy))
        if len(names) != n_devices:
            raise ValueError(f"{len(names)} policies for "
                             f"{n_devices} devices")
        names = [LEGACY_MODES.get(n, n) for n in names]
        self.n_devices = n_devices
        self.placement = placement
        self.health_config = health
        self.shed_policy = shed_policy
        self._health: List[Optional[DeviceHealth]] = [
            DeviceHealth(d, health) if health is not None else None
            for d in range(n_devices)]
        self.executors: List[DeviceExecutor] = [
            DeviceExecutor(policy=name, wait_mode=wait_mode,
                           poll_interval=poll_interval, device_index=d,
                           trace=ExecutorTrace() if trace else None,
                           health=self._health[d])
            for d, name in enumerate(names)]
        if admission is None:
            if len(set(names)) != 1:
                raise ValueError(
                    "heterogeneous per-device approaches need an explicit "
                    "AdmissionController (one RTA must price the platform)")
            # the executors may have coerced wait_mode (kthread forces
            # busy); price admission with the mode actually enforced
            admission = AdmissionController(
                policy=names[0], wait_mode=self.executors[0].wait_mode,
                n_cpus=n_cpus, epsilon_ms=epsilon_ms,
                try_gpu_priorities=try_gpu_priorities,
                n_devices=n_devices)
        if admission.n_devices != n_devices:
            raise ValueError(
                f"admission controller models {admission.n_devices} "
                f"devices, cluster has {n_devices}")
        self.admission = admission
        # optional durability: a sched.store.JobStore that journals every
        # admit→place→bind transaction (inside the transaction lock, so
        # journal order == admission order — the property recovery's
        # decision-conformance re-run depends on) and every release
        self.store = store
        self._lock = threading.Lock()     # admit→place→bind transaction
        self._bindings: Dict[int, int] = {}   # job.uid -> device
        self._jobs: List[RTJob] = []
        self._rr = 0                      # round-robin cursor
        # ---- fault-containment state (DESIGN.md §10) ----
        self.epoch = 0                    # binding epoch (0 = pristine)
        self._failed: set = set()         # failed device indices
        # uid -> device tombstones: an evicted/displaced job's dying
        # thread still routes on_job_complete to the executor it ran on
        # (without this, _route would fall through to bind_job and
        # resurrect the binding the fail-over just severed)
        self._dead: Dict[int, int] = {}
        # per-name resubmission material: profile as admitted, workload
        # spec + live body/workload object, iteration count, started
        # flag — what fail-over rebinding and shed-resume rebuild a job
        # from (jobs bound via bind_job bypass admission and have none)
        self._meta: Dict[str, dict] = {}
        self._shed_meta: Dict[str, dict] = {}   # name -> meta of shed jobs
        self._mon_stop = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        if health is not None:
            self._monitor = threading.Thread(
                target=self._health_loop, daemon=True,
                name="cluster-health")
            self._monitor.start()

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _admitted_load(self, device: int) -> float:
        """GPU utilization already admitted onto ``device`` — O(1),
        served from the admission controller's running per-device
        totals (the placement strategies query this per candidate per
        submission, so it sits on the admission hot path)."""
        return self.admission.device_utilization(device)

    def candidates(self, prof: JobProfile,
                   strategy: Optional[str] = None) -> List[int]:
        """Device try-order for ``prof`` under ``strategy`` (defaults to
        the cluster's placement).  ``pinned`` honors ``prof.device``;
        the others return every device, best candidate first — each is
        admission-tested before committing (see :meth:`submit`)."""
        s = strategy or self.placement
        if s == "pinned":
            return [prof.device] if prof.device not in self._failed else []
        if s == "round_robin":
            return [d for d in ((self._rr + i) % self.n_devices
                                for i in range(self.n_devices))
                    if d not in self._failed]
        if s == "least_loaded":
            return sorted((d for d in range(self.n_devices)
                           if d not in self._failed),
                          key=lambda d: (self._admitted_load(d), d))
        raise ValueError(f"unknown placement {s!r}")

    def live_devices(self) -> List[int]:
        """Devices not declared failed, least-loaded first — the
        candidate order fail-over rebinding and shed-resume use."""
        return sorted((d for d in range(self.n_devices)
                       if d not in self._failed),
                      key=lambda d: (self._admitted_load(d), d))

    # ------------------------------------------------------------------
    # the admit→place→bind transaction
    # ------------------------------------------------------------------
    def submit(self, prof: JobProfile, workload=None, body=None, *,
               strategy: Optional[str] = None, n_iterations: int = 1,
               start: bool = False,
               stop_after_s: Optional[float] = None) -> AdmissionDecision:
        """Deprecated direct-submission path: go through the unified
        facade instead — ``repro.sched.connect(...)`` returns a
        ``SchedClient`` whose ``submit`` works identically against an
        in-process cluster and the daemon socket (DESIGN.md §9)."""
        warnings.warn(
            "direct ClusterExecutor.submit() is deprecated; submit "
            "through repro.sched.connect() -> SchedClient.submit()",
            DeprecationWarning, stacklevel=2)
        return self._submit(prof, workload, body, strategy=strategy,
                            n_iterations=n_iterations, start=start,
                            stop_after_s=stop_after_s)

    def _submit(self, prof: JobProfile, workload=None, body=None, *,
                strategy: Optional[str] = None, n_iterations: int = 1,
                start: bool = False,
                stop_after_s: Optional[float] = None,
                journal_meta: Optional[Mapping] = None
                ) -> AdmissionDecision:
        """Admit → place → bind in one transaction.

        For each candidate device (in placement order) the profile is
        rebound to that device and the full cross-device admission test
        re-run; the first admitted placement wins, and the job is built
        already bound to it (``RTJob.device`` set, binding recorded) —
        there is no window where an admitted job is unplaced or a placed
        job unadmitted.  Returns the :class:`AdmissionDecision` extended
        with ``device`` and ``job`` (both None when every placement was
        refused; the decision then carries the last refusal).

        Exactly one of ``workload`` (a ``core.segments.SegmentedWorkload``,
        bound to the winning device) or ``body`` (a plain RTJob body)
        must be given.  ``start=True`` releases the job immediately.

        With a :class:`~repro.sched.store.JobStore` attached, the whole
        transaction is journaled *inside the lock* (profile, decision
        with WCRT evidence, winning device, and ``journal_meta``'s
        workload spec / iteration count), so the journal's accepted-
        decision order is exactly the admission order."""
        if (workload is None) == (body is None):
            raise ValueError("pass exactly one of workload= or body=")
        meta = dict(journal_meta or {})
        with self._lock:
            last: Optional[AdmissionDecision] = None
            for dev in self.candidates(prof, strategy):
                cand = (prof if prof.device == dev
                        else dataclasses.replace(prof, device=dev))
                res = self.admission.try_admit(cand)
                if not res["admitted"]:
                    last = res
                    continue
                job_body = (workload.bind(self, device=dev)
                            if workload is not None else body)
                job = RTJob(prof.name, job_body,
                            period_s=prof.period_ms / 1e3,
                            priority=prof.priority,
                            deadline_s=(prof.deadline_ms or
                                        prof.period_ms) / 1e3,
                            best_effort=prof.best_effort,
                            n_iterations=n_iterations, device=dev)
                self._bindings[job.uid] = dev
                self._jobs.append(job)
                if strategy == "round_robin" or (
                        strategy is None and
                        self.placement == "round_robin"):
                    self._rr = (dev + 1) % self.n_devices
                out = AdmissionDecision(res).bound(dev, job)
                self._meta[prof.name] = {
                    "profile": cand, "workload": meta.get("workload"),
                    "workload_obj": workload, "body": body,
                    "n_iterations": n_iterations,
                    "started": bool(start), "stop_after_s": stop_after_s}
                if self.store is not None:
                    self.store.record_decision(
                        cand, out, device=dev,
                        workload=meta.get("workload"),
                        n_iterations=n_iterations,
                        epoch=self.epoch or None,
                        request_id=meta.get("request_id"))
                # overload degradation ladder: the RT guarantee is
                # analytical (BE never interferes in any RTA) but the
                # device is physical — shed best-effort work before
                # the admission leaves it oversubscribed
                self._maybe_shed_locked(dev, exclude=prof.name)
                if start:
                    job.start(self, stop_after_s)
                return out
            if last is None:
                # every candidate device is failed (or pinned to one):
                # an explicit refusal, not a misleading rta-reject
                last = AdmissionDecision.refuse(
                    "validation-refused",
                    error=f"no live device for job {prof.name!r} "
                          f"(failed: {sorted(self._failed)})")
            out = AdmissionDecision(last).bound(None, None)
            if self.store is not None:
                self.store.record_decision(prof, out, device=None,
                                           workload=meta.get("workload"),
                                           n_iterations=n_iterations,
                                           epoch=self.epoch or None,
                                           request_id=meta.get(
                                               "request_id"))
            return out

    def bind_job(self, job: RTJob, device: Optional[int] = None
                 ) -> DeviceExecutor:
        """Pin an externally built job to a device (``submit`` does this
        automatically; use this for jobs that bypass admission, e.g.
        microbenchmarks).  Rebinding to a different device raises — the
        migration-free invariant."""
        dev = job.device if device is None else device
        if dev is None:
            raise ValueError(f"job {job.name!r} has no device: pass "
                             "device= or set RTJob(device=...)")
        if not (0 <= dev < self.n_devices):
            raise ValueError(f"device {dev} out of range for "
                             f"{self.n_devices}-device cluster")
        with self._lock:
            prev = self._bindings.get(job.uid)
            if prev is not None and prev != dev:
                raise RuntimeError(
                    f"migration-free invariant: job {job.name!r} is bound "
                    f"to device {prev}, refusing rebind to {dev}")
            self._bindings[job.uid] = dev
            if job not in self._jobs:
                self._jobs.append(job)
        job.device = dev
        return self.executors[dev]

    # ------------------------------------------------------------------
    # fault containment: device fail-over (binding epochs) and the
    # overload degradation ladder (DESIGN.md §10)
    # ------------------------------------------------------------------
    def fail_device(self, device: int, reason: str = "") -> dict:
        """Declare ``device`` failed and open a new binding epoch.

        Everything happens in one transaction under the cluster lock,
        mirroring admit→place→bind:

          1. the fail-over marker is journaled (on replay it moves the
             device's jobs to the *displaced* ledger — nothing may stay
             there, the no-silent-job-loss audit);
          2. the device's executor is failed (in-flight and future
             dispatches raise :class:`DeviceFailedError` — the orderly
             stop ``RTJob`` absorbs) and its jobs are evicted, unbound,
             and tombstoned;
          3. the new epoch re-derives **every** surviving job's
             admission afresh, in the original admission order, on the
             original devices — guaranteed to re-accept (removing a
             task only decreases interference) — and journals the fresh
             WCRT evidence, so recovery's decision-conformance replay
             holds in the new epoch too.  The surviving ``RTJob``\\ s
             are untouched: no migration, and their MORT stays bounded
             by (now provably slack) WCRT;
          4. the displaced jobs are re-run through ``try_admit_many``
             against the surviving devices; each outcome — re-bound as
             a *new* job with fresh evidence, or explicitly refused —
             is journaled, settling its displaced-ledger entry.

        Returns a summary dict (``epoch``, ``kept``, ``rebound``,
        ``refused``).  Idempotent: failing a failed device is a no-op.
        """
        with self._lock:
            return self._fail_device_locked(device, reason)

    def _fail_device_locked(self, device: int, reason: str) -> dict:
        if not (0 <= device < self.n_devices):
            raise ValueError(f"device {device} out of range for "
                             f"{self.n_devices}-device cluster")
        if device in self._failed:
            return {"device": device, "epoch": self.epoch,
                    "already_failed": True, "kept": [], "rebound": [],
                    "refused": []}
        self._failed.add(device)
        self.epoch += 1
        epoch = self.epoch
        if self.store is not None:
            self.store.record_failover(device, epoch, reason)
        self.executors[device].fail(reason)
        # sever the victims' bindings (their threads die orderly at the
        # next preemption point; tombstones keep their completion path
        # routed to the executor they actually ran on)
        for job in [j for j in self._jobs
                    if self._bindings.get(j.uid) == device]:
            job.evict(f"device {device} failed"
                      + (f": {reason}" if reason else ""))
            self._dead[job.uid] = device
            self._jobs.remove(job)
            self._bindings.pop(job.uid, None)
        displaced = [p for p in self.admission.admitted
                     if p.device == device]
        unaffected = [p for p in self.admission.admitted
                      if p.device != device]
        # -- step 3: fresh evidence for every survivor ------------------
        # the epoch reset goes through the ``admitted`` setter, which
        # invalidates the warm-start cache; the sequential re-admissions
        # below repopulate it as each survivor is re-proven
        self.admission.admitted = []
        kept: List[str] = []
        for p in unaffected:
            dec = self.admission.try_admit(p)
            if not dec["admitted"]:  # pragma: no cover — monotonicity
                raise RuntimeError(
                    f"fail-over invariant violated: surviving job "
                    f"{p.name!r} refused on re-admission in epoch "
                    f"{epoch}: {dec.get('error') or dec['wcrt']}")
            if self.store is not None:
                m = self._meta.get(p.name, {})
                self.store.record_decision(
                    p, dec.bound(p.device, None), device=p.device,
                    workload=m.get("workload"),
                    n_iterations=m.get("n_iterations", 1), epoch=epoch)
            kept.append(p.name)
        # -- step 4: displaced jobs vs the survivors --------------------
        rebound: List[dict] = []
        refused: List[str] = []
        survivors = self.live_devices()
        cands = [dataclasses.replace(p, device=survivors[
            i % len(survivors)]) if survivors else p
            for i, p in enumerate(displaced)]
        decs = (self.admission.try_admit_many(cands)
                if survivors else
                [AdmissionDecision.refuse(
                    "validation-refused",
                    error="no surviving device") for _ in cands])
        for p, cand, dec in zip(displaced, cands, decs):
            if not dec["admitted"]:
                # first placement refused: try the remaining survivors
                for d in survivors:
                    if d == cand.device:
                        continue
                    retry = dataclasses.replace(p, device=d)
                    rdec = self.admission.try_admit(retry)
                    if rdec["admitted"]:
                        cand, dec = retry, rdec
                        break
            if dec["admitted"]:
                out = self._spawn_locked(cand, dec, epoch=epoch)
                rebound.append({"job": p.name, "from": device,
                                "to": cand.device,
                                "wcrt": out.get("wcrt", {})})
            else:
                if self.store is not None:
                    m = self._meta.get(p.name, {})
                    self.store.record_decision(
                        cand, AdmissionDecision(dec).bound(None, None),
                        device=None, workload=m.get("workload"),
                        n_iterations=m.get("n_iterations", 1),
                        epoch=epoch)
                self._meta.pop(p.name, None)
                refused.append(p.name)
        return {"device": device, "epoch": epoch, "reason": reason,
                "kept": kept, "rebound": rebound, "refused": refused}

    def _spawn_locked(self, prof: JobProfile, dec: AdmissionDecision,
                      *, epoch: Optional[int]) -> AdmissionDecision:
        """Build + bind + journal a job from its stored resubmission
        material — the rebinding path of fail-over and shed-resume.
        The admission (``try_admit``) has already accepted ``prof`` on
        ``prof.device``; caller holds the cluster lock."""
        m = self._meta.get(prof.name, {})
        wl, body = m.get("workload_obj"), m.get("body")
        job_body = (wl.bind(self, device=prof.device)
                    if wl is not None else body)
        n_iterations = m.get("n_iterations", 1)
        job = RTJob(prof.name, job_body,
                    period_s=prof.period_ms / 1e3,
                    priority=prof.priority,
                    deadline_s=(prof.deadline_ms or
                                prof.period_ms) / 1e3,
                    best_effort=prof.best_effort,
                    n_iterations=n_iterations, device=prof.device)
        self._bindings[job.uid] = prof.device
        self._jobs.append(job)
        out = AdmissionDecision(dec).bound(prof.device, job)
        self._meta[prof.name] = dict(m, profile=prof)
        if self.store is not None:
            self.store.record_decision(
                prof, out, device=prof.device,
                workload=m.get("workload"),
                n_iterations=n_iterations, epoch=epoch)
        if m.get("started") and job_body is not None:
            job.start(self, m.get("stop_after_s"))
        return out

    def _maybe_shed_locked(self, device: int,
                           exclude: Optional[str] = None) -> List[str]:
        """Run the degradation ladder on ``device``: evict best-effort
        jobs (lowest tier first) until total utilization is back under
        ``shed_policy.shed_at``.  ``exclude`` protects the job whose
        admission triggered the check from being its own victim."""
        pol = self.shed_policy
        if pol is None:
            return []
        victims = [v for v in plan_shedding(
            self.admission.on_device(device), pol.shed_at,
            tier_budgets=pol.tier_budgets)
            if v.name != exclude]
        for v in victims:
            self._shed_job_locked(v, f"overload on device {device}: "
                                     f"shed_at={pol.shed_at:g}")
        return [v.name for v in victims]

    def _shed_job_locked(self, prof: JobProfile, reason: str) -> None:
        self.admission.release(prof.name)
        if self.store is not None:
            self.store.record_shed(prof.name, reason)
        for job in [j for j in self._jobs if j.name == prof.name]:
            job.evict(f"shed: {reason}")
            self._dead[job.uid] = self._bindings.pop(job.uid,
                                                     prof.device)
            self._jobs.remove(job)
        self._shed_meta[prof.name] = dict(self._meta.get(prof.name, {}),
                                          profile=prof)

    def _maybe_resume_locked(self) -> List[str]:
        """Hysteretic re-admission of shed jobs: a victim comes back
        only onto a live device whose total utilization *with it
        re-included* stays under ``resume_at < shed_at``, so the ladder
        cannot oscillate at the shed boundary.  Called whenever
        capacity frees up (``release``)."""
        pol = self.shed_policy
        resumed: List[str] = []
        if pol is None or not self._shed_meta:
            return resumed
        for name in list(self._shed_meta):
            m = self._shed_meta[name]
            prof = m.get("profile")
            if prof is None:
                continue
            for dev in self.live_devices():
                cand = (prof if prof.device == dev
                        else dataclasses.replace(prof, device=dev))
                if not can_resume(cand, self.admission.on_device(dev),
                                  pol.resume_at,
                                  tier_budgets=pol.tier_budgets):
                    continue
                dec = self.admission.try_admit(cand)
                if dec["admitted"]:
                    del self._shed_meta[name]
                    self._meta[name] = dict(m, profile=cand)
                    self._spawn_locked(cand, dec,
                                       epoch=self.epoch or None)
                    resumed.append(name)
                    break
        return resumed

    def _health_loop(self) -> None:
        cfg = self.health_config
        while not self._mon_stop.is_set():
            for d, h in enumerate(self._health):
                if h is None or d in self._failed:
                    continue
                if h.check() == FAILED and cfg.auto_failover:
                    self.fail_device(d, reason=h.reason
                                     or "health monitor verdict")
            self._mon_stop.wait(cfg.poll_interval_s)

    def restore_fault_state(self, epoch: int,
                            failed_devices) -> None:
        """Recovery hook (``SchedDaemon``): a device the journal says
        failed stays failed across restarts — the journaled epoch's
        re-admissions were proven against the surviving platform, so
        the recovered daemon must come back AS that platform."""
        with self._lock:
            self.epoch = max(self.epoch, int(epoch))
            for d in failed_devices:
                if 0 <= d < self.n_devices and d not in self._failed:
                    self._failed.add(d)
                    self.executors[d].fail("journaled device failure "
                                           "(restored on recovery)")

    def device_health(self, device: int) -> Optional[DeviceHealth]:
        return self._health[device]

    @property
    def failed_devices(self) -> List[int]:
        return sorted(self._failed)

    @property
    def shed_jobs(self) -> List[str]:
        return sorted(self._shed_meta)

    # ------------------------------------------------------------------
    # executor protocol (routed by the job's binding) — an RTJob can be
    # started on the cluster, and SegmentedWorkload.run() dispatches
    # through these without knowing the platform is multi-device
    # ------------------------------------------------------------------
    def executor_for(self, device: int) -> DeviceExecutor:
        if not (0 <= device < self.n_devices):
            raise ValueError(f"device {device} out of range for "
                             f"{self.n_devices}-device cluster")
        return self.executors[device]

    def _route(self, job: RTJob) -> DeviceExecutor:
        dev = self._bindings.get(job.uid)
        if dev is None:
            # a job whose binding was severed by fail-over or shedding
            # is tombstoned: its dying thread's on_job_complete must
            # reach the executor it actually ran on, not re-bind
            dead = self._dead.get(job.uid)
            if dead is not None:
                return self.executors[dead]
            return self.bind_job(job)   # adopts job.device (raises if unset)
        if job.device is not None and job.device != dev:
            raise RuntimeError(
                f"migration-free invariant: job {job.name!r} bound to "
                f"device {dev} now claims device {job.device}")
        return self.executors[dev]

    def on_job_start(self, job: RTJob) -> None:
        self._route(job).on_job_start(job)

    def on_job_complete(self, job: RTJob) -> None:
        self._route(job).on_job_complete(job)

    def device_segment(self, job: RTJob):
        return self._route(job).device_segment(job)

    def run(self, job: RTJob, program, *args, **kw):
        return self._route(job).run(job, program, *args, **kw)

    def run_sliced(self, job: RTJob, op, **kw):
        return self._route(job).run_sliced(job, op, **kw)

    # ------------------------------------------------------------------
    # cluster-wide stats / invariants
    # ------------------------------------------------------------------
    @property
    def traces(self) -> List[Optional[ExecutorTrace]]:
        return [ex.trace for ex in self.executors]

    def per_device_mort(self) -> Dict[int, Optional[float]]:
        """Max observed response time per device (s), ``None`` for a
        device with no completions yet (same no-silent-0.0 rule as
        ``JobStats.mort``)."""
        out: Dict[int, Optional[float]] = {d: None
                                           for d in range(self.n_devices)}
        for job in self._jobs:
            m = job.stats.mort
            d = self._bindings[job.uid]
            if m is not None and (out[d] is None or m > out[d]):
                out[d] = m
        return out

    def per_model_stats(self) -> Dict[str, dict]:
        """Per-job observability, keyed by job name: binding, tier,
        criticality, release/completion/deadline-miss counts, and the
        response-time tail (MORT + nearest-rank p50/p99, ms).  ``None``
        latency fields before the first completion — an idle model must
        not read as a 0 ms tail (same rule as ``JobStats.mort``)."""
        profs = {p.name: p for p in self.admission.admitted}
        out: Dict[str, dict] = {}
        for job in list(self._jobs):
            p = profs.get(job.name)
            st = job.stats
            rts = sorted(st.response_times)
            out[job.name] = {
                "device": self._bindings.get(job.uid, job.device),
                "tier": tier_of(p) if p is not None else 0,
                "best_effort": (p.best_effort if p is not None
                                else job.priority == BEST_EFFORT),
                "utilization": (profile_utilization(p)
                                if p is not None else None),
                "releases": st.releases,
                "completions": st.completions,
                "deadline_misses": st.deadline_misses,
                "mort_ms": rts[-1] * 1e3 if rts else None,
                "p50_ms": nearest_rank(rts, 0.50) * 1e3 if rts else None,
                "p99_ms": nearest_rank(rts, 0.99) * 1e3 if rts else None,
            }
        return out

    def per_tier_stats(self) -> Dict[int, dict]:
        """Tier-level rollup of :meth:`per_model_stats`: job names,
        pooled response-time tail, summed miss/completion counts, the
        tier's admitted utilization (total and the budgeted best-effort
        share), and — when a :class:`ShedPolicy` with tier budgets is
        armed — the tier's per-device budget.  Tiers appear once any
        admitted profile or live job carries them."""
        per_model = self.per_model_stats()
        pooled: Dict[int, List[float]] = {}
        rows: Dict[int, dict] = {}
        for job in list(self._jobs):
            m = per_model.get(job.name)
            if m is None:
                continue
            t = m["tier"]
            row = rows.setdefault(t, {
                "jobs": [], "releases": 0, "completions": 0,
                "deadline_misses": 0})
            row["jobs"].append(job.name)
            row["releases"] += m["releases"]
            row["completions"] += m["completions"]
            row["deadline_misses"] += m["deadline_misses"]
            pooled.setdefault(t, []).extend(job.stats.response_times)
        util_all = tier_utilization(self.admission.admitted,
                                    best_effort_only=False)
        util_be = tier_utilization(self.admission.admitted)
        for t in util_all:
            rows.setdefault(t, {"jobs": [], "releases": 0,
                                "completions": 0, "deadline_misses": 0})
        pol = self.shed_policy
        for t, row in rows.items():
            rts = sorted(pooled.get(t, []))
            row["jobs"] = sorted(row["jobs"])
            row["utilization"] = util_all.get(t, 0.0)
            row["be_utilization"] = util_be.get(t, 0.0)
            row["budget"] = pol.budget_for(t) if pol is not None else None
            row["mort_ms"] = rts[-1] * 1e3 if rts else None
            row["p50_ms"] = (nearest_rank(rts, 0.50) * 1e3
                             if rts else None)
            row["p99_ms"] = (nearest_rank(rts, 0.99) * 1e3
                             if rts else None)
        return rows

    def stats(self) -> dict:
        return {
            "per_device_mort": self.per_device_mort(),
            "per_model": self.per_model_stats(),
            "per_tier": self.per_tier_stats(),
            "dispatches": {d: ex.dispatches
                           for d, ex in enumerate(self.executors)},
            "updates": {d: len(ex.update_times)
                        for d, ex in enumerate(self.executors)},
            "jobs": {d: sorted(j.name for j in self._jobs
                               if self._bindings[j.uid] == d)
                     for d in range(self.n_devices)},
            "epoch": self.epoch,
            "failed_devices": self.failed_devices,
            "shed": self.shed_jobs,
            "health": {d: (h.state if h is not None else None)
                       for d, h in enumerate(self._health)},
            "admission_latency": self.admission.latency_summary(),
        }

    def find_job(self, name: str) -> Optional[RTJob]:
        """The live (newest) RTJob submitted under ``name``, or None —
        the daemon's status/MORT reporting looks jobs up by name."""
        with self._lock:
            for job in reversed(self._jobs):
                if job.name == name:
                    return job
        return None

    def assert_migration_free(self) -> None:
        """Every job's dispatches all happened on its bound device.
        Checked against the executor traces when tracing is on; the
        binding table (which refuses rebinds) is re-verified always."""
        for job in self._jobs:
            bound = self._bindings[job.uid]
            if job.device != bound:
                raise AssertionError(
                    f"job {job.name!r}: binding table says device "
                    f"{bound}, job says {job.device}")
        # dispatches are keyed by job uid, not name: a released name may
        # legitimately be resubmitted onto another device as a new job
        seen: Dict[int, int] = {}
        for ex in self.executors:
            if ex.trace is None:
                continue
            for e in ex.trace.events:
                if e.event != "dispatch":
                    continue
                uid = e.info.get("uid")
                prev = seen.setdefault(uid, e.device)
                if prev != e.device:
                    raise AssertionError(
                        f"job {e.job!r} dispatched on devices {prev} "
                        f"and {e.device} — migration detected")

    # ------------------------------------------------------------------
    def release(self, name: str) -> bool:
        """Retire a finished job: its admission profile stops charging
        future placements and the name becomes submittable again (the
        retired job also leaves the cluster's stats/invariant views, so
        a resubmitted name cannot read as a migration).  Without this, a
        completed job's demand would inflate every later admission test
        and its name would be refused as a duplicate forever.  The
        caller keeps the RTJob object (and its stats)."""
        with self._lock:
            for job in [j for j in self._jobs if j.name == name]:
                self._jobs.remove(job)
                self._bindings.pop(job.uid, None)
            released = self.admission.release(name)
            self._meta.pop(name, None)
            was_shed = self._shed_meta.pop(name, None) is not None
            if (released or was_shed) and self.store is not None:
                self.store.record_release(name)
            # freed capacity may let a shed best-effort job climb back
            # up the degradation ladder (hysteresis in resume_at)
            self._maybe_resume_locked()
            return released or was_shed

    def join(self, timeout: Optional[float] = None) -> None:
        for job in self._jobs:
            job.join(timeout)

    def shutdown(self) -> None:
        self._mon_stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=1.0)
        for ex in self.executors:
            ex.shutdown()
