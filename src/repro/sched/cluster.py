"""Multi-device runtime: one preemptive executor per accelerator, behind
a placement-aware admission gate (DESIGN.md §7).

``ClusterExecutor`` mirrors the simulator's one-policy-per-device
structure on the live side: it owns one :class:`DeviceExecutor` (and one
``SchedulingPolicy`` instance, resolved per device from the
`core/policy` registry) for every device of an N-device platform, and an
:class:`AdmissionController` configured for that platform as the
cluster-wide gatekeeper — the PR 2 cross-device busy-wait fixed point
(`core/crossfix.py`) finally feeds a real multi-executor runtime.

The placement layer decides *where* an arriving workload runs:

  * ``pinned``       — honor ``JobProfile.device`` verbatim;
  * ``round_robin``  — rotate over devices, next-free-first;
  * ``least_loaded`` — try devices in increasing admitted-GPU-utilization
    order.

Every candidate placement is re-tested by the cross-device admission
analysis *before* committing (``try_admit`` on the profile rebound to
the candidate device), and admit→place→bind happens in one transaction
under the cluster lock: a job only ever exists bound to the device its
admission was proven on.  The binding is immutable — the migration-free
invariant — so the per-device RTAs' assumption that a task's device
segments all execute on ``task.device`` holds by construction, and
``assert_migration_free()`` re-verifies it from the executor traces.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, Union

from ..core.policy import LEGACY_MODES
from .admission import AdmissionController, AdmissionDecision, JobProfile
from .executor import DeviceExecutor, ExecutorTrace
from .job import RTJob

if TYPE_CHECKING:  # pragma: no cover
    from .store import JobStore

PLACEMENTS = ("pinned", "round_robin", "least_loaded")


class ClusterExecutor:
    """N preemptive device executors + placement-aware admission.

    ``policy`` is a registry name applied to every device, or a
    per-device sequence of names (one policy instance is built per
    device either way).  ``admission`` overrides the internally built
    :class:`AdmissionController` (required when per-device approaches
    are heterogeneous, since one RTA must price the whole platform).
    ``trace=True`` attaches an :class:`ExecutorTrace` to every executor
    (the conformance harness's input)."""

    def __init__(self, n_devices: int,
                 policy: Union[str, Sequence[str]] = "ioctl",
                 wait_mode: str = "suspend",
                 poll_interval: float = 0.001,
                 n_cpus: int = 4, epsilon_ms: float = 1.0,
                 placement: str = "pinned",
                 try_gpu_priorities: bool = True,
                 trace: bool = False,
                 admission: Optional[AdmissionController] = None,
                 store: Optional["JobStore"] = None):
        if n_devices < 1:
            raise ValueError("a cluster needs at least one device")
        if placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r} "
                             f"(available: {PLACEMENTS})")
        names = ([policy] * n_devices if isinstance(policy, str)
                 else list(policy))
        if len(names) != n_devices:
            raise ValueError(f"{len(names)} policies for "
                             f"{n_devices} devices")
        names = [LEGACY_MODES.get(n, n) for n in names]
        self.n_devices = n_devices
        self.placement = placement
        self.executors: List[DeviceExecutor] = [
            DeviceExecutor(policy=name, wait_mode=wait_mode,
                           poll_interval=poll_interval, device_index=d,
                           trace=ExecutorTrace() if trace else None)
            for d, name in enumerate(names)]
        if admission is None:
            if len(set(names)) != 1:
                raise ValueError(
                    "heterogeneous per-device approaches need an explicit "
                    "AdmissionController (one RTA must price the platform)")
            # the executors may have coerced wait_mode (kthread forces
            # busy); price admission with the mode actually enforced
            admission = AdmissionController(
                mode=names[0], wait_mode=self.executors[0].wait_mode,
                n_cpus=n_cpus, epsilon_ms=epsilon_ms,
                try_gpu_priorities=try_gpu_priorities,
                n_devices=n_devices)
        if admission.n_devices != n_devices:
            raise ValueError(
                f"admission controller models {admission.n_devices} "
                f"devices, cluster has {n_devices}")
        self.admission = admission
        # optional durability: a sched.store.JobStore that journals every
        # admit→place→bind transaction (inside the transaction lock, so
        # journal order == admission order — the property recovery's
        # decision-conformance re-run depends on) and every release
        self.store = store
        self._lock = threading.Lock()     # admit→place→bind transaction
        self._bindings: Dict[int, int] = {}   # job.uid -> device
        self._jobs: List[RTJob] = []
        self._rr = 0                      # round-robin cursor

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _admitted_load(self, device: int) -> float:
        """GPU utilization already admitted onto ``device``."""
        load = 0.0
        for p in self.admission.admitted:
            if p.device == device:
                load += sum(m + e for m, e in
                            p.device_segments_ms) / p.period_ms
        return load

    def candidates(self, prof: JobProfile,
                   strategy: Optional[str] = None) -> List[int]:
        """Device try-order for ``prof`` under ``strategy`` (defaults to
        the cluster's placement).  ``pinned`` honors ``prof.device``;
        the others return every device, best candidate first — each is
        admission-tested before committing (see :meth:`submit`)."""
        s = strategy or self.placement
        if s == "pinned":
            return [prof.device]
        if s == "round_robin":
            return [(self._rr + i) % self.n_devices
                    for i in range(self.n_devices)]
        if s == "least_loaded":
            return sorted(range(self.n_devices),
                          key=lambda d: (self._admitted_load(d), d))
        raise ValueError(f"unknown placement {s!r}")

    # ------------------------------------------------------------------
    # the admit→place→bind transaction
    # ------------------------------------------------------------------
    def submit(self, prof: JobProfile, workload=None, body=None, *,
               strategy: Optional[str] = None, n_iterations: int = 1,
               start: bool = False,
               stop_after_s: Optional[float] = None) -> AdmissionDecision:
        """Deprecated direct-submission path: go through the unified
        facade instead — ``repro.sched.connect(...)`` returns a
        ``SchedClient`` whose ``submit`` works identically against an
        in-process cluster and the daemon socket (DESIGN.md §9)."""
        warnings.warn(
            "direct ClusterExecutor.submit() is deprecated; submit "
            "through repro.sched.connect() -> SchedClient.submit()",
            DeprecationWarning, stacklevel=2)
        return self._submit(prof, workload, body, strategy=strategy,
                            n_iterations=n_iterations, start=start,
                            stop_after_s=stop_after_s)

    def _submit(self, prof: JobProfile, workload=None, body=None, *,
                strategy: Optional[str] = None, n_iterations: int = 1,
                start: bool = False,
                stop_after_s: Optional[float] = None,
                journal_meta: Optional[Mapping] = None
                ) -> AdmissionDecision:
        """Admit → place → bind in one transaction.

        For each candidate device (in placement order) the profile is
        rebound to that device and the full cross-device admission test
        re-run; the first admitted placement wins, and the job is built
        already bound to it (``RTJob.device`` set, binding recorded) —
        there is no window where an admitted job is unplaced or a placed
        job unadmitted.  Returns the :class:`AdmissionDecision` extended
        with ``device`` and ``job`` (both None when every placement was
        refused; the decision then carries the last refusal).

        Exactly one of ``workload`` (a ``core.segments.SegmentedWorkload``,
        bound to the winning device) or ``body`` (a plain RTJob body)
        must be given.  ``start=True`` releases the job immediately.

        With a :class:`~repro.sched.store.JobStore` attached, the whole
        transaction is journaled *inside the lock* (profile, decision
        with WCRT evidence, winning device, and ``journal_meta``'s
        workload spec / iteration count), so the journal's accepted-
        decision order is exactly the admission order."""
        if (workload is None) == (body is None):
            raise ValueError("pass exactly one of workload= or body=")
        meta = dict(journal_meta or {})
        with self._lock:
            last: Optional[AdmissionDecision] = None
            for dev in self.candidates(prof, strategy):
                cand = (prof if prof.device == dev
                        else dataclasses.replace(prof, device=dev))
                res = self.admission.try_admit(cand)
                if not res["admitted"]:
                    last = res
                    continue
                job_body = (workload.bind(self, device=dev)
                            if workload is not None else body)
                job = RTJob(prof.name, job_body,
                            period_s=prof.period_ms / 1e3,
                            priority=prof.priority,
                            deadline_s=(prof.deadline_ms or
                                        prof.period_ms) / 1e3,
                            best_effort=prof.best_effort,
                            n_iterations=n_iterations, device=dev)
                self._bindings[job.uid] = dev
                self._jobs.append(job)
                if strategy == "round_robin" or (
                        strategy is None and
                        self.placement == "round_robin"):
                    self._rr = (dev + 1) % self.n_devices
                out = AdmissionDecision(res).bound(dev, job)
                if self.store is not None:
                    self.store.record_decision(
                        cand, out, device=dev,
                        workload=meta.get("workload"),
                        n_iterations=n_iterations)
                if start:
                    job.start(self, stop_after_s)
                return out
            out = AdmissionDecision(
                last if last is not None else {}).bound(None, None)
            if self.store is not None:
                self.store.record_decision(prof, out, device=None,
                                           workload=meta.get("workload"),
                                           n_iterations=n_iterations)
            return out

    def bind_job(self, job: RTJob, device: Optional[int] = None
                 ) -> DeviceExecutor:
        """Pin an externally built job to a device (``submit`` does this
        automatically; use this for jobs that bypass admission, e.g.
        microbenchmarks).  Rebinding to a different device raises — the
        migration-free invariant."""
        dev = job.device if device is None else device
        if dev is None:
            raise ValueError(f"job {job.name!r} has no device: pass "
                             "device= or set RTJob(device=...)")
        if not (0 <= dev < self.n_devices):
            raise ValueError(f"device {dev} out of range for "
                             f"{self.n_devices}-device cluster")
        with self._lock:
            prev = self._bindings.get(job.uid)
            if prev is not None and prev != dev:
                raise RuntimeError(
                    f"migration-free invariant: job {job.name!r} is bound "
                    f"to device {prev}, refusing rebind to {dev}")
            self._bindings[job.uid] = dev
            if job not in self._jobs:
                self._jobs.append(job)
        job.device = dev
        return self.executors[dev]

    # ------------------------------------------------------------------
    # executor protocol (routed by the job's binding) — an RTJob can be
    # started on the cluster, and SegmentedWorkload.run() dispatches
    # through these without knowing the platform is multi-device
    # ------------------------------------------------------------------
    def executor_for(self, device: int) -> DeviceExecutor:
        if not (0 <= device < self.n_devices):
            raise ValueError(f"device {device} out of range for "
                             f"{self.n_devices}-device cluster")
        return self.executors[device]

    def _route(self, job: RTJob) -> DeviceExecutor:
        dev = self._bindings.get(job.uid)
        if dev is None:
            return self.bind_job(job)   # adopts job.device (raises if unset)
        if job.device is not None and job.device != dev:
            raise RuntimeError(
                f"migration-free invariant: job {job.name!r} bound to "
                f"device {dev} now claims device {job.device}")
        return self.executors[dev]

    def on_job_start(self, job: RTJob) -> None:
        self._route(job).on_job_start(job)

    def on_job_complete(self, job: RTJob) -> None:
        self._route(job).on_job_complete(job)

    def device_segment(self, job: RTJob):
        return self._route(job).device_segment(job)

    def run(self, job: RTJob, program, *args, **kw):
        return self._route(job).run(job, program, *args, **kw)

    def run_sliced(self, job: RTJob, op, **kw):
        return self._route(job).run_sliced(job, op, **kw)

    # ------------------------------------------------------------------
    # cluster-wide stats / invariants
    # ------------------------------------------------------------------
    @property
    def traces(self) -> List[Optional[ExecutorTrace]]:
        return [ex.trace for ex in self.executors]

    def per_device_mort(self) -> Dict[int, Optional[float]]:
        """Max observed response time per device (s), ``None`` for a
        device with no completions yet (same no-silent-0.0 rule as
        ``JobStats.mort``)."""
        out: Dict[int, Optional[float]] = {d: None
                                           for d in range(self.n_devices)}
        for job in self._jobs:
            m = job.stats.mort
            d = self._bindings[job.uid]
            if m is not None and (out[d] is None or m > out[d]):
                out[d] = m
        return out

    def stats(self) -> dict:
        return {
            "per_device_mort": self.per_device_mort(),
            "dispatches": {d: ex.dispatches
                           for d, ex in enumerate(self.executors)},
            "updates": {d: len(ex.update_times)
                        for d, ex in enumerate(self.executors)},
            "jobs": {d: sorted(j.name for j in self._jobs
                               if self._bindings[j.uid] == d)
                     for d in range(self.n_devices)},
        }

    def find_job(self, name: str) -> Optional[RTJob]:
        """The live (newest) RTJob submitted under ``name``, or None —
        the daemon's status/MORT reporting looks jobs up by name."""
        with self._lock:
            for job in reversed(self._jobs):
                if job.name == name:
                    return job
        return None

    def assert_migration_free(self) -> None:
        """Every job's dispatches all happened on its bound device.
        Checked against the executor traces when tracing is on; the
        binding table (which refuses rebinds) is re-verified always."""
        for job in self._jobs:
            bound = self._bindings[job.uid]
            if job.device != bound:
                raise AssertionError(
                    f"job {job.name!r}: binding table says device "
                    f"{bound}, job says {job.device}")
        # dispatches are keyed by job uid, not name: a released name may
        # legitimately be resubmitted onto another device as a new job
        seen: Dict[int, int] = {}
        for ex in self.executors:
            if ex.trace is None:
                continue
            for e in ex.trace.events:
                if e.event != "dispatch":
                    continue
                uid = e.info.get("uid")
                prev = seen.setdefault(uid, e.device)
                if prev != e.device:
                    raise AssertionError(
                        f"job {e.job!r} dispatched on devices {prev} "
                        f"and {e.device} — migration detected")

    # ------------------------------------------------------------------
    def release(self, name: str) -> bool:
        """Retire a finished job: its admission profile stops charging
        future placements and the name becomes submittable again (the
        retired job also leaves the cluster's stats/invariant views, so
        a resubmitted name cannot read as a migration).  Without this, a
        completed job's demand would inflate every later admission test
        and its name would be refused as a duplicate forever.  The
        caller keeps the RTJob object (and its stats)."""
        with self._lock:
            for job in [j for j in self._jobs if j.name == name]:
                self._jobs.remove(job)
                self._bindings.pop(job.uid, None)
            released = self.admission.release(name)
            if released and self.store is not None:
                self.store.record_release(name)
            return released

    def join(self, timeout: Optional[float] = None) -> None:
        for job in self._jobs:
            job.join(timeout)

    def shutdown(self) -> None:
        for ex in self.executors:
            ex.shutdown()
