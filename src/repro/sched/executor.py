"""Preemptive priority-based device executor — the TPU-native realization
of the paper's runlist control (see DESIGN.md §2).

The device (or mesh slice) executes one XLA program at a time; the
executor's admission state decides *whose* programs may dispatch.  Two
modes realize the paper's two approaches:

  * ``notify`` (IOCTL approach): jobs bracket device segments with the
    ``device_segment(job)`` context manager.  Admission follows Algorithm 2
    verbatim over (task_running, task_pending); the runlist-update critical
    section is guarded by a mutex (the rt_mutex analogue) and its measured
    cost is the epsilon of the analysis (benchmarks/overhead.py).

  * ``poll`` (kernel-thread approach): a scheduler thread polls job states
    every ``poll_interval`` and reserves the device for the
    highest-priority active real-time job at *job* granularity — no job
    code changes (opaque jobs).

Preemption takes effect at program boundaries: before each dispatch the
executor re-checks that the calling job is still admitted (and otherwise
waits, busy-spinning or suspending per ``wait_mode``).  Long device work
should be chunked (microbatches / decode chunks) to bound the preemption
delay — the epsilon analogue of thread-block-boundary preemption.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

import jax

from .job import RTJob


class DeviceExecutor:
    def __init__(self, mode: str = "notify", wait_mode: str = "suspend",
                 poll_interval: float = 0.001):
        assert mode in ("notify", "poll", "unmanaged")
        assert wait_mode in ("busy", "suspend")
        if mode == "poll" and wait_mode != "busy":
            # Sec. V-A: self-suspension would be misread as a state change
            wait_mode = "busy"
        self.mode = mode
        self.wait_mode = wait_mode
        self.poll_interval = poll_interval
        self._mutex = threading.Lock()      # runlist-update rt_mutex
        self._cv = threading.Condition(self._mutex)
        self.task_running: List[RTJob] = []  # Algorithm 2 state
        self.task_pending: List[RTJob] = []
        self.reserved: Optional[RTJob] = None  # poll mode reservation
        self._active: List[RTJob] = []       # jobs currently in a release
        self._device_lock = threading.Lock()  # serializes program dispatch
        self.update_times: List[float] = []   # measured epsilon samples
        self.dispatches = 0
        self._poller: Optional[threading.Thread] = None
        self._stop = threading.Event()
        if mode == "poll":
            self._poller = threading.Thread(target=self._poll_loop,
                                            daemon=True, name="kthread")
            self._poller.start()

    # ------------------------------------------------------------------
    # job lifecycle (state changes the polling scheduler watches)
    # ------------------------------------------------------------------
    def on_job_start(self, job: RTJob) -> None:
        with self._mutex:
            self._active.append(job)

    def on_job_complete(self, job: RTJob) -> None:
        with self._mutex:
            if job in self._active:
                self._active.remove(job)
            if job in self.task_running:
                self.task_running.remove(job)
            if job in self.task_pending:
                self.task_pending.remove(job)
            if self.reserved is job:
                self.reserved = None
            self._cv.notify_all()

    def shutdown(self) -> None:
        self._stop.set()
        if self._poller:
            self._poller.join(timeout=1.0)

    # ------------------------------------------------------------------
    # poll mode: Algorithm 1 (job-granular reservation)
    # ------------------------------------------------------------------
    def _poll_loop(self) -> None:
        prev: Optional[RTJob] = None
        while not self._stop.is_set():
            with self._mutex:
                rt = [j for j in self._active if j.is_rt]
                new = max(rt, key=lambda j: j.device_priority, default=None)
                if new is not prev:
                    t0 = time.perf_counter()
                    self.reserved = new          # runlist rewrite
                    self._cv.notify_all()
                    self.update_times.append(time.perf_counter() - t0)
                    prev = new
            time.sleep(self.poll_interval)

    # ------------------------------------------------------------------
    # notify mode: Algorithm 2 (segment-granular admission)
    # ------------------------------------------------------------------
    def _ioctl_add(self, job: RTJob) -> None:
        t0 = time.perf_counter()
        if not job.is_rt:
            if not any(j.is_rt for j in self.task_running):
                self.task_running.append(job)
            else:
                self.task_pending.append(job)
        else:
            tau_h = max(self.task_running,
                        key=lambda j: j.device_priority, default=None)
            if tau_h is None or job.device_priority > tau_h.device_priority:
                self.task_running.append(job)
                if tau_h is not None:
                    self.task_running.remove(tau_h)
                    self.task_pending.append(tau_h)
            else:
                self.task_pending.append(job)
        self.update_times.append(time.perf_counter() - t0)
        self._cv.notify_all()

    def _ioctl_remove(self, job: RTJob) -> None:
        t0 = time.perf_counter()
        rt_pend = [j for j in self.task_pending if j.is_rt]
        if rt_pend:
            tau_k = max(rt_pend, key=lambda j: j.device_priority)
            self.task_pending.remove(tau_k)
            self.task_running.append(tau_k)
        else:
            self.task_running.extend(self.task_pending)
            self.task_pending.clear()
        if job in self.task_running:
            self.task_running.remove(job)
        self.update_times.append(time.perf_counter() - t0)
        self._cv.notify_all()

    # ------------------------------------------------------------------
    # admission check used at every program boundary
    # ------------------------------------------------------------------
    def _admitted(self, job: RTJob) -> bool:
        if self.mode == "unmanaged":
            return True
        if self.mode == "poll":
            return (self.reserved is job) or \
                (self.reserved is None and not job.is_rt) or \
                (self.reserved is None and job.is_rt)
        if job not in self.task_running:
            return False
        rt = [j for j in self.task_running if j.is_rt]
        if rt:
            return job is max(rt, key=lambda j: j.device_priority)
        return True

    def _wait_admitted(self, job: RTJob) -> None:
        if self.wait_mode == "busy":
            while True:
                with self._mutex:
                    if self._admitted(job):
                        return
                time.sleep(0)  # busy-wait (yielding spin)
        else:
            with self._cv:
                while not self._admitted(job):
                    self._cv.wait(timeout=0.05)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    class _Segment:
        def __init__(self, ex: "DeviceExecutor", job: RTJob):
            self.ex, self.job = ex, job

        def __enter__(self):
            if self.ex.mode == "notify":
                with self.ex._mutex:
                    self.ex._ioctl_add(self.job)
            return self

        def __exit__(self, *exc):
            if self.ex.mode == "notify":
                with self.ex._mutex:
                    self.ex._ioctl_remove(self.job)
            return False

    def device_segment(self, job: RTJob) -> "_Segment":
        """The single macro of the IOCTL approach (begin+end)."""
        return DeviceExecutor._Segment(self, job)

    def run(self, job: RTJob, program: Callable, *args, **kw):
        """Dispatch one device program for ``job``; blocks until the result
        is ready.  Re-checks admission first (preemption point)."""
        self._wait_admitted(job)
        with self._device_lock:
            self.dispatches += 1
            out = program(*args, **kw)
            jax.block_until_ready(out)
        return out
