"""Preemptive priority-based device executor — the TPU-native realization
of the paper's runlist control (see DESIGN.md §2).

The device (or mesh slice) executes one XLA program at a time; a
``SchedulingPolicy`` resolved from the `repro.core.policy` registry decides
*whose* programs may dispatch.  The policy object is the very same class
the simulator drives, so Algorithms 1 and 2 have exactly one
implementation:

  * ``policy="ioctl"`` (legacy ``mode="notify"``): jobs bracket device
    segments with the ``device_segment(job)`` context manager.  Admission
    follows Algorithm 2 over the shared ``Alg2State``
    (task_running/task_pending); the runlist-update critical section is
    guarded by a mutex (the rt_mutex analogue) and its measured cost is
    the epsilon of the analysis (benchmarks/overhead.py).

  * ``policy="kthread"`` (legacy ``mode="poll"``): a scheduler thread
    polls job states every ``poll_interval`` and reserves the device for
    the highest-priority active real-time job at *job* granularity via the
    shared ``pick_reserved`` — no job code changes (opaque jobs).

  * ``policy="unmanaged"``: every dispatch is admitted (default driver).

Any other registered policy (e.g. ``sync_priority``) works the same way:
the executor only ever talks to the runtime face of ``SchedulingPolicy``.

Preemption takes effect at program boundaries: before each dispatch the
executor re-checks that the calling job is still admitted (and otherwise
waits, busy-spinning or suspending per ``wait_mode``).  Long device work
goes through ``run_sliced`` — a ``repro.core.segments.SlicedOp`` dispatched
K grid-slices at a time with an explicit carry — so the preemption delay
is *enforced* to be at most one slice (the epsilon analogue of
thread-block-boundary preemption), measured per slice into
``job.stats.slice_times``, and checkpointable mid-op (DESIGN.md §6).
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Union

import jax

from ..core.policy import (LEGACY_MODES, SchedulingPolicy, make_policy)
from . import faultinject
from .fault import DeviceFailedError, DeviceHealth, JobEvicted
from .job import RTJob


@dataclass(frozen=True)
class TraceEvent:
    """One executor event: ``start``/``complete`` (job lifecycle),
    ``preempt``/``resume``/``dispatch`` (admission at a program boundary),
    or ``update`` (a runlist rewrite, with the policy-state snapshot the
    conformance harness replays — DESIGN.md §7)."""
    t: float                  # time.monotonic() at emission
    device: int               # DeviceExecutor.device_index
    event: str
    job: str                  # job name ("" for a poll update clearing it)
    info: dict = field(default_factory=dict)


class ExecutorTrace:
    """Lightweight event recorder attached to a ``DeviceExecutor``.

    Every emission happens under the executor's runlist mutex, so the
    event order *is* the order the policy state machine saw — which is
    what lets ``tests/conformance.py`` replay the recorded update
    sequence through a fresh ``Alg2State``/``pick_reserved`` and through
    the simulator, and assert decision-for-decision agreement."""

    def __init__(self):
        self.events: List[TraceEvent] = []

    def emit(self, device: int, event: str, job: str = "", **info) -> None:
        self.events.append(TraceEvent(time.monotonic(), device, event,
                                      job, info))

    def of(self, *events: str) -> List[TraceEvent]:
        return [e for e in self.events if e.event in events]

    def jobs(self) -> List[str]:
        return sorted({e.job for e in self.events if e.event == "start"})


class DeviceExecutor:
    def __init__(self, mode: Optional[str] = None,
                 wait_mode: str = "suspend",
                 poll_interval: float = 0.001,
                 policy: Union[str, SchedulingPolicy, None] = None,
                 device_index: int = 0,
                 trace: Optional[ExecutorTrace] = None,
                 health: Optional[DeviceHealth] = None,
                 fault_injector: Optional[
                     "faultinject.FaultInjector"] = None):
        """``policy`` is a registry name (or instance); the historical
        ``mode`` argument ("notify"/"poll"/"unmanaged") keeps working and
        maps onto the registry names.  ``device_index`` names the
        accelerator this executor drives on a multi-device platform
        (``sched.cluster.ClusterExecutor`` owns one executor per device);
        ``trace`` attaches an :class:`ExecutorTrace` event recorder.
        ``health`` attaches a :class:`~repro.sched.fault.DeviceHealth`
        slice-level heartbeat (armed around every dispatch);
        ``fault_injector`` installs a deterministic fault plan — when
        omitted, ``$REPRO_FAULT_PLAN`` is consulted so a daemon under
        chaos test injects its own faults (DESIGN.md §10)."""
        assert wait_mode in ("busy", "suspend")
        if mode is not None:
            # the seed executor's construction surface, superseded twice
            # over: policy names come from the registry, submission goes
            # through repro.sched.connect() -> SchedClient (DESIGN.md §9)
            warnings.warn(
                "DeviceExecutor(mode=...) is deprecated; pass a registry "
                "policy name (policy=...) — and submit jobs through "
                "repro.sched.connect() -> SchedClient",
                DeprecationWarning, stacklevel=2)
        if policy is None:
            policy = mode if mode is not None else "ioctl"
        if isinstance(policy, str):
            self.policy_name = LEGACY_MODES.get(policy, policy)
            self.policy = make_policy(self.policy_name)
        else:
            self.policy = policy
            self.policy_name = policy.name
        if self.policy.requires_busy_wait and wait_mode != "busy":
            # Sec. V-A: self-suspension would be misread as a state change
            wait_mode = "busy"
        # historic mode label (admission.py, benchmarks still read it)
        _back = {v: k for k, v in LEGACY_MODES.items()}
        self.mode = mode if mode is not None else _back.get(
            self.policy_name, self.policy_name)
        self.wait_mode = wait_mode
        self.poll_interval = poll_interval
        self.device_index = device_index
        self.trace = trace
        self.health = health
        self.fault_injector = (fault_injector if fault_injector is not None
                               else faultinject.from_env())
        self.failed = False               # set by fail(); never cleared
        self.fail_reason = ""
        self._mutex = threading.Lock()      # runlist-update rt_mutex
        self._cv = threading.Condition(self._mutex)
        self._active: List[RTJob] = []       # jobs currently in a release
        self._device_lock = threading.Lock()  # serializes program dispatch
        self.update_times: List[float] = []   # measured epsilon samples
        self.dispatches = 0
        self._poller: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.policy.runtime_attach(self)
        if self.policy.wants_poll_thread:
            self._poller = threading.Thread(target=self._poll_loop,
                                            daemon=True, name="kthread")
            self._poller.start()

    # ------------------------------------------------------------------
    # Algorithm 2 state views (API compatibility with the seed executor)
    # ------------------------------------------------------------------
    @property
    def task_running(self) -> List[RTJob]:
        return getattr(self.policy, "running", [])

    @property
    def task_pending(self) -> List[RTJob]:
        return getattr(self.policy, "pending", [])

    @property
    def reserved(self) -> Optional[RTJob]:
        return getattr(self.policy, "reserved", None)

    # ------------------------------------------------------------------
    # job lifecycle (state changes the polling scheduler watches)
    # ------------------------------------------------------------------
    def on_job_start(self, job: RTJob) -> None:
        with self._mutex:
            self._active.append(job)
            self.policy.runtime_on_start(job)
            self._emit("start", job, priority=job.priority,
                       device_priority=job.device_priority, rt=job.is_rt)

    def on_job_complete(self, job: RTJob) -> None:
        with self._mutex:
            if job in self._active:
                self._active.remove(job)
            self.policy.runtime_on_complete(job)
            self._emit("complete", job)
            self._cv.notify_all()

    def shutdown(self) -> None:
        self._stop.set()
        if self._poller:
            self._poller.join(timeout=1.0)

    def fail(self, reason: str = "") -> None:
        """Declare this device failed (fail-over entry point): every
        dispatch — in flight, waiting, or future — raises
        :class:`DeviceFailedError`, and suspended waiters are woken so
        they observe the verdict immediately.  Permanent: a failed
        device never rejoins an epoch (the cluster would need a fresh
        executor, i.e. a fresh binding epoch, anyway)."""
        with self._mutex:
            self.failed = True
            self.fail_reason = reason
            self._emit("device_failed", None, reason=reason)
            self._cv.notify_all()

    # ------------------------------------------------------------------
    # poll mode: Algorithm 1 (job-granular reservation, shared rule)
    # ------------------------------------------------------------------
    def _poll_loop(self) -> None:
        while not self._stop.is_set():
            with self._mutex:
                rt = [j for j in self._active if j.is_rt]
                decision = self.policy.runtime_pick(rt)
                # time only the rewrite, not the job-list scan — the scan
                # is the paper's negligible polling check (footnote 3)
                t0 = time.perf_counter()
                if self.policy.runtime_apply(decision):
                    self._cv.notify_all()
                    self.update_times.append(time.perf_counter() - t0)
                    self._emit(
                        "update", decision, which="poll",
                        reserved=decision.name if decision else None,
                        candidates=tuple((j.name, j.device_priority)
                                         for j in rt))
            time.sleep(self.poll_interval)

    # ------------------------------------------------------------------
    # notify mode: Algorithm 2 entry points (caller holds self._mutex).
    # Thin shims over the shared policy state machine, kept for the seed
    # executor's API; device_segment() is the public path.
    # ------------------------------------------------------------------
    def _ioctl_add(self, job: RTJob) -> None:
        t0 = time.perf_counter()
        rewrote = self.policy.runtime_segment_begin(job)
        self.update_times.append(time.perf_counter() - t0)
        self._emit_alg2("begin", job, rewrote)
        self._cv.notify_all()

    def _ioctl_remove(self, job: RTJob) -> None:
        t0 = time.perf_counter()
        rewrote = self.policy.runtime_segment_end(job)
        self.update_times.append(time.perf_counter() - t0)
        self._emit_alg2("end", job, rewrote)
        self._cv.notify_all()

    # ------------------------------------------------------------------
    # trace emission (no-ops when no ExecutorTrace is attached); every
    # call site holds self._mutex, so the event order is the order the
    # policy state machine saw
    # ------------------------------------------------------------------
    def _emit(self, event: str, job: Optional[RTJob], **info) -> None:
        if self.trace is not None:
            self.trace.emit(self.device_index, event,
                            job.name if job is not None else "", **info)

    def _emit_alg2(self, which: str, job: RTJob, rewrote) -> None:
        if self.trace is not None:
            self._emit("update", job, which=which, rewrote=bool(rewrote),
                       running=tuple(j.name for j in self.task_running),
                       pending=tuple(j.name for j in self.task_pending))

    # ------------------------------------------------------------------
    # admission check used at every program boundary
    # ------------------------------------------------------------------
    def _admitted(self, job: RTJob) -> bool:
        return self.policy.runtime_admitted(job)

    def _wait_admitted(self, job: RTJob) -> None:
        # "preempt" is emitted on the first denied check, "resume" when
        # admission comes back, "dispatch" at every admission pass — all
        # under the mutex, so a dispatch event is totally ordered against
        # the runlist updates that justified it (conformance harness).
        blocked = False
        if self.wait_mode == "busy":
            while True:
                with self._mutex:
                    self._check_containment(job)
                    if self._admitted(job):
                        if blocked:
                            self._emit("resume", job)
                        self._emit("dispatch", job, uid=job.uid)
                        return
                    if not blocked:
                        blocked = True
                        self._emit("preempt", job)
                # busy-wait: a sub-poll-interval yield, not sleep(0) — a
                # zero-sleep spin churns the GIL hard enough to starve
                # the *running* job's thread on CPython, which shows up
                # as cross-device interference a real spinning core
                # would never cause
                time.sleep(0.0005)
        else:
            with self._cv:
                while True:
                    self._check_containment(job)
                    if self._admitted(job):
                        break
                    if not blocked:
                        blocked = True
                        self._emit("preempt", job)
                    self._cv.wait(timeout=0.05)
                if blocked:
                    self._emit("resume", job)
                self._emit("dispatch", job, uid=job.uid)

    def _check_containment(self, job: RTJob) -> None:
        """Raise the orderly-stop verdict at a preemption point: a
        failed device (fail-over) or an evicted job (load shedding)
        must not dispatch again — the containment boundary of
        DESIGN.md §10.  Called with the mutex held or not; reads only
        monotonic flags."""
        if self.failed:
            raise DeviceFailedError(
                f"device {self.device_index} failed"
                + (f": {self.fail_reason}" if self.fail_reason else ""))
        if job.evicted:
            raise JobEvicted(f"job {job.name!r} evicted "
                             f"({job.evict_reason or 'shed'})")

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    class _Segment:
        def __init__(self, ex: "DeviceExecutor", job: RTJob):
            self.ex, self.job = ex, job

        def __enter__(self):
            if self.ex.policy.needs_segment_hooks:
                with self.ex._mutex:
                    self.ex._ioctl_add(self.job)
            return self

        def __exit__(self, *exc):
            if self.ex.policy.needs_segment_hooks:
                with self.ex._mutex:
                    self.ex._ioctl_remove(self.job)
            return False

    def device_segment(self, job: RTJob) -> "_Segment":
        """The single macro of the IOCTL approach (begin+end)."""
        return DeviceExecutor._Segment(self, job)

    def run(self, job: RTJob, program: Callable, *args, **kw):
        """Dispatch one device program for ``job``; blocks until the result
        is ready.  Re-checks admission first (preemption point)."""
        self._wait_admitted(job)
        with self._device_lock:
            self.dispatches += 1
            if self.health is not None:
                self.health.slice_begin(job.name, -1)
            try:
                if self.fault_injector is not None:
                    self.fault_injector.fire(device=self.device_index,
                                             job=job.name, slice_idx=-1)
                out = program(*args, **kw)
                jax.block_until_ready(out)
            except (DeviceFailedError, JobEvicted):
                raise
            except Exception as e:  # noqa: BLE001 — health accounting
                if self.health is not None:
                    self.health.record_error(job.name, e)
                raise
            finally:
                if self.health is not None:
                    self.health.slice_end()
        return out

    def run_sliced(self, job: RTJob, op, *,
                   carry=None, start: int = 0,
                   checkpoint: Optional[Callable] = None,
                   checkpoint_every: int = 0):
        """Dispatch a :class:`repro.core.segments.SlicedOp` slice by slice.

        Admission is re-checked before *every* slice, so a higher-priority
        job waits at most one in-flight slice (+ the runlist-update ε) —
        the bounded preemption delay the analysis assumes, instead of the
        whole-op wait of a single monolithic dispatch.  Per-slice wall
        times land in ``job.stats.slice_times`` (the measured ε-analogue
        profile).

        ``carry``/``start`` resume from a snapshot; ``checkpoint(i, carry)``
        is called (outside the device lock) after every
        ``checkpoint_every``-th slice, e.g. ``sched.checkpointer.
        save_carry`` — a preempted or crashed job restarts mid-op rather
        than re-running the segment."""
        if carry is None:
            carry = op.init()
        for i in range(start, op.n_slices):
            self._wait_admitted(job)
            carry = self._dispatch_slice(job, op.step, carry, i)
            if checkpoint is not None and checkpoint_every > 0 \
                    and (i + 1) % checkpoint_every == 0:
                checkpoint(i + 1, carry)
        self._wait_admitted(job)
        return self._dispatch_slice(job, lambda c, _i: op.finalize(c),
                                    carry, op.n_slices)

    def _dispatch_slice(self, job: RTJob, step, carry, i: int):
        """One slice under the device lock: the health heartbeat is
        armed for exactly the in-flight window (a hung kernel reads as
        a stalled armed beat), the fault injector fires at the dispatch
        point, and a slice exception lands in the device's health
        record before propagating (DESIGN.md §10)."""
        with self._device_lock:
            self.dispatches += 1
            if self.health is not None:
                self.health.slice_begin(job.name, i)
            t0 = time.perf_counter()
            try:
                if self.fault_injector is not None:
                    self.fault_injector.fire(device=self.device_index,
                                             job=job.name, slice_idx=i)
                out = step(carry, i)
                jax.block_until_ready(out)
            except (DeviceFailedError, JobEvicted):
                raise
            except Exception as e:  # noqa: BLE001 — health accounting
                if self.health is not None:
                    self.health.record_error(job.name, e)
                raise
            finally:
                if self.health is not None:
                    self.health.slice_end()
            job.stats.slice_times.append(time.perf_counter() - t0)
        return out
