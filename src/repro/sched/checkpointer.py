"""Sharded, atomic, versioned checkpointing (fault-tolerance substrate).

Layout:  <dir>/step_<n>/{manifest.json, arr_<i>.npy ...}; the manifest
records the pytree structure and leaf metadata.  Writes go to a temp dir
renamed into place (atomic on POSIX), so a crash never corrupts the latest
checkpoint.  ``restore`` re-shards onto whatever mesh/shardings the caller
provides — the primitive behind elastic re-scaling (elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's npy format cannot round-trip ml_dtypes (bf16 loads as void);
# such arrays are stored as raw-bit views with the logical dtype recorded
# in the manifest.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic save of a pytree; returns the final directory."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _BITCAST:
            arr = arr.view(_BITCAST[str(arr.dtype)])
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr,
                allow_pickle=False)
    meta = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
            "dtypes": dtypes, "time": time.time()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; with ``shardings`` the
    leaves are placed sharded (possibly onto a different mesh than the one
    that saved them — elastic re-scaling)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like)
    out = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves))
    for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(os.path.join(d, f"arr_{i}.npy"))
        saved_dt = meta["dtypes"][i]
        if saved_dt in _BITCAST:
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt)))
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# mid-job carry snapshots (sliced device segments, DESIGN.md §6)
# --------------------------------------------------------------------------

def save_carry(ckpt_dir: str, label: str, slice_idx: int,
               carry: Any) -> str:
    """Snapshot a SlicedOp carry after ``slice_idx`` completed slices.
    The carry is an ordinary pytree (softmax row stats, recurrent state,
    KV cache + emitted tokens, ...), so the sharded/atomic ``save`` works
    unchanged; a job resumes with ``executor.run_sliced(job, op,
    carry=carry, start=slice_idx)`` instead of re-running the segment."""
    return save(os.path.join(ckpt_dir, f"carry_{label}"), slice_idx, carry)


def latest_carry(ckpt_dir: str, label: str, like: Any
                 ) -> Optional[tuple]:
    """(slice_idx, carry) of the latest snapshot for ``label``, restored
    into the structure of ``like`` (use ``op.init()``), or ``None`` when
    no snapshot exists."""
    d = os.path.join(ckpt_dir, f"carry_{label}")
    idx = latest_step(d)
    if idx is None:
        return None
    return idx, restore(d, like, step=idx)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (training never stalls on
    I/O); ``wait()`` drains before shutdown."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _work():
            save(self.ckpt_dir, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
