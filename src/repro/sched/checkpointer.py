"""Sharded, atomic, versioned checkpointing (fault-tolerance substrate).

Layout:  <dir>/step_<n>/{manifest.json, arr_<i>.npy ...}; the manifest
records the pytree structure and leaf metadata.  Writes go to a temp dir
renamed into place (atomic on POSIX), so a crash never corrupts the latest
checkpoint.  ``restore`` re-shards onto whatever mesh/shardings the caller
provides — the primitive behind elastic re-scaling (elastic.py).
"""
from __future__ import annotations

import atexit
import contextlib
import json
import os
import shutil
import threading
import time
import weakref
from typing import Any, Iterator, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy's npy format cannot round-trip ml_dtypes (bf16 loads as void);
# such arrays are stored as raw-bit views with the logical dtype recorded
# in the manifest.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic save of a pytree; returns the final directory."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtypes.append(str(arr.dtype))
        if str(arr.dtype) in _BITCAST:
            arr = arr.view(_BITCAST[str(arr.dtype)])
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr,
                allow_pickle=False)
    meta = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
            "dtypes": dtypes, "time": time.time()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


# steps currently being read by a `restore` call, keyed by
# (abspath(ckpt_dir), step) with a reader count — `AsyncCheckpointer._gc`
# must not delete a step out from under a concurrent restore (the
# restore would crash mid-read on a missing arr_<i>.npy)
_READERS_LOCK = threading.Lock()
_READERS: dict = {}


@contextlib.contextmanager
def _reading(ckpt_dir: str, step: int) -> Iterator[Tuple[str, int]]:
    """Read-guard for one checkpoint step: while held, the step is
    exempt from ``AsyncCheckpointer._gc`` deletion."""
    key = (os.path.abspath(ckpt_dir), step)
    with _READERS_LOCK:
        _READERS[key] = _READERS.get(key, 0) + 1
    try:
        yield key
    finally:
        with _READERS_LOCK:
            n = _READERS.get(key, 1) - 1
            if n <= 0:
                _READERS.pop(key, None)
            else:
                _READERS[key] = n


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; with ``shardings`` the
    leaves are placed sharded (possibly onto a different mesh than the one
    that saved them — elastic re-scaling)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    with _reading(ckpt_dir, step):
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(like)
        out = []
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves))
        for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = np.load(os.path.join(d, f"arr_{i}.npy"))
            saved_dt = meta["dtypes"][i]
            if saved_dt in _BITCAST:
                arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt)))
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# mid-job carry snapshots (sliced device segments, DESIGN.md §6)
# --------------------------------------------------------------------------

def save_carry(ckpt_dir: str, label: str, slice_idx: int,
               carry: Any) -> str:
    """Snapshot a SlicedOp carry after ``slice_idx`` completed slices.
    The carry is an ordinary pytree (softmax row stats, recurrent state,
    KV cache + emitted tokens, ...), so the sharded/atomic ``save`` works
    unchanged; a job resumes with ``executor.run_sliced(job, op,
    carry=carry, start=slice_idx)`` instead of re-running the segment."""
    return save(os.path.join(ckpt_dir, f"carry_{label}"), slice_idx, carry)


def latest_carry(ckpt_dir: str, label: str, like: Any
                 ) -> Optional[tuple]:
    """(slice_idx, carry) of the latest snapshot for ``label``, restored
    into the structure of ``like`` (use ``op.init()``), or ``None`` when
    no snapshot exists."""
    d = os.path.join(ckpt_dir, f"carry_{label}")
    idx = latest_step(d)
    if idx is None:
        return None
    return idx, restore(d, like, step=idx)


# all live AsyncCheckpointers, drained once at interpreter exit: the
# worker is a daemon thread, so without this an in-flight save is killed
# mid-write at shutdown and silently dropped (the tmp-rename keeps the
# *previous* checkpoint intact, but the newest state is lost — exactly
# the checkpoint a crash-recovery path wants)
_LIVE: "weakref.WeakSet[AsyncCheckpointer]" = weakref.WeakSet()


def _drain_at_exit() -> None:
    for ckpt in list(_LIVE):
        try:
            ckpt.wait()
        except Exception:  # noqa: BLE001 — exit path must not raise
            pass


atexit.register(_drain_at_exit)


class AsyncCheckpointer:
    """Fire-and-forget saves on a worker thread (training never stalls on
    I/O); ``wait()`` drains before shutdown, and an atexit hook drains
    every live instance so interpreter exit cannot drop an in-flight
    save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        _LIVE.add(self)

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _work():
            save(self.ckpt_dir, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        root = os.path.abspath(self.ckpt_dir)
        for s in steps[:-self.keep]:
            with _READERS_LOCK:
                busy = _READERS.get((root, s), 0) > 0
            if busy:
                # a concurrent restore is reading this step (e.g. a
                # FaultTolerantLoop rollback racing the post-save gc):
                # skip it now, the next gc pass collects it
                continue
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
