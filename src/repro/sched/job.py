"""Real-time jobs for the device executor.

An ``RTJob`` is the runtime realization of the paper's task model: its
execution alternates host (CPU) segments and device (GPU) segments, it has
a fixed priority (and an optionally distinct device priority, Sec. V-C),
and it is released periodically.

Two integration styles mirror the paper's two approaches:
  * annotated jobs call ``executor.device_segment(job)`` around their
    device work (the IOCTL approach's two macros collapse into one context
    manager);
  * opaque jobs only expose ``run_once()`` — the polling scheduler manages
    them with no code changes (the kernel-thread approach).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from .fault import FaultContained

BEST_EFFORT = -1_000_000


class JobState:
    IDLE = "idle"
    READY = "ready"
    RUNNING = "running"
    DONE = "done"


@dataclass
class JobStats:
    releases: int = 0
    completions: int = 0
    response_times: List[float] = field(default_factory=list)
    deadline_misses: int = 0
    slice_times: List[float] = field(default_factory=list)  # seconds

    @property
    def mort(self) -> Optional[float]:
        """Maximum observed response time, or ``None`` before the first
        completion — an idle job must not read as a 0.0 MORT (i.e. as
        trivially meeting its deadline) in overhead/case-study reports."""
        return max(self.response_times) if self.response_times else None

    @property
    def max_slice_time(self) -> Optional[float]:
        """Longest single sliced dispatch (s) — the preemption-delay bound
        this job imposes on higher-priority arrivals."""
        return max(self.slice_times) if self.slice_times else None


class RTJob:
    """A periodically released job executing ``body(job, iteration)``.

    ``body`` runs on the job's own thread; device segments inside it go
    through the executor (which enforces preemptive priority scheduling at
    program boundaries)."""

    _uid = itertools.count()

    def __init__(self, name: str, body: Callable, period_s: float,
                 priority: int, deadline_s: Optional[float] = None,
                 device_priority: Optional[int] = None,
                 best_effort: bool = False, n_iterations: int = 1,
                 device: Optional[int] = None):
        self.uid = next(RTJob._uid)
        self.name = name
        self.body = body
        self.period_s = period_s
        self.deadline_s = deadline_s or period_s
        self.priority = BEST_EFFORT if best_effort else priority
        # a best-effort job has no real-time priority on either side of
        # the platform: an explicit device_priority is ignored for BE
        # jobs, or Alg2State.top_running could rank a BE member above an
        # arriving RT job and push the RT job to task_pending behind
        # best-effort work (found by tests/test_policy_fuzz.py)
        self.device_priority = (self.priority
                                if device_priority is None or best_effort
                                else device_priority)
        self.best_effort = best_effort
        self.n_iterations = n_iterations
        # accelerator this job's device segments are bound to; None until
        # placed (ClusterExecutor.submit / bind_job set it, and the
        # migration-free invariant keeps it fixed for the job's lifetime)
        self.device = device
        self.state = JobState.IDLE
        self.stats = JobStats()
        self.release_time = 0.0
        # containment bookkeeping (DESIGN.md §10): evicted is the
        # platform's orderly-stop verdict (load shedding / fail-over
        # drain) — the executor raises FaultContained at the next
        # preemption point; error records why a job ended abnormally,
        # so a dead body is observable instead of a silently lost thread
        self.evicted = False
        self.evict_reason = ""
        self.error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def is_rt(self) -> bool:
        return not self.best_effort

    # ------------------------------------------------------------------
    def start(self, executor, stop_after_s: Optional[float] = None) -> None:
        self._thread = threading.Thread(
            target=self._run, args=(executor, stop_after_s),
            name=f"job-{self.name}", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()

    def evict(self, reason: str = "") -> None:
        """Orderly mid-segment stop: the executor raises ``JobEvicted``
        at the job's next preemption point (slice boundary), so an
        evicted sliced job loses at most the slices since its last
        checkpointed carry — the resume point of a shed job."""
        self.evicted = True
        self.evict_reason = reason
        self._stop.set()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread:
            self._thread.join(timeout)

    def _run(self, executor, stop_after_s) -> None:
        t0 = time.monotonic()
        next_release = t0
        for it in range(self.n_iterations):
            if self._stop.is_set():
                break
            if stop_after_s is not None \
                    and time.monotonic() - t0 >= stop_after_s:
                break
            now = time.monotonic()
            if now < next_release:
                time.sleep(next_release - now)
            self.release_time = max(next_release, now)
            next_release = self.release_time + self.period_s
            self.state = JobState.RUNNING
            self.stats.releases += 1
            executor.on_job_start(self)
            try:
                self.body(self, it)
            except FaultContained as e:
                # orderly platform stop (eviction / device fail-over):
                # the iteration did not complete — no completion, no
                # response-time sample — but the job ends cleanly and
                # the verdict is observable on job.error
                self.error = e
                break
            except Exception as e:  # noqa: BLE001 — no silent job loss
                # a body failure must surface as state, not as a dead
                # thread whose traceback nobody joined on
                self.error = e
                break
            finally:
                executor.on_job_complete(self)
            resp = time.monotonic() - self.release_time
            self.stats.completions += 1
            self.stats.response_times.append(resp)
            if resp > self.deadline_s and self.is_rt:
                self.stats.deadline_misses += 1
            self.state = JobState.READY
        self.state = JobState.DONE
