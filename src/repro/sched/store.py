"""Journaled job store: the durable face of the cluster runtime.

The store makes an admitted job's guarantee survive the process that
admitted it (DESIGN.md §9).  It records, per job: the submitted
``JobProfile``, the :class:`~repro.sched.admission.AdmissionDecision`
with its WCRT evidence (journaled verbatim — the decision dict *is* the
JSON record), the immutable device binding chosen by the
admit→place→bind transaction, the workload spec (a registry name +
kwargs the daemon can reconstruct the job body from), and the latest
checkpointed carry pointer of a sliced job mid-segment.

Durability discipline:

  * **append-only journal** (``journal.jsonl``): one JSON record per
    line, flushed + fsync'd per append.  The journal order of accepted
    decisions IS the admission order — ``ClusterExecutor`` appends
    inside its transaction lock — which is what lets recovery re-run
    admission over the journaled taskset and assert it reproduces the
    recorded decisions (`AdmissionController.rebuild`).
  * **atomic snapshot compaction** (``snapshot.json``): the folded
    state is written to a temp file and ``os.replace``'d into place
    (the same tmp-rename discipline as ``checkpointer.save``), then the
    journal is atomically replaced by an empty one.  A crash between
    the two replaces leaves snapshot *and* old journal — replay is
    idempotent (records fold by job name), so the double-apply is
    harmless.
  * **carries** live under ``<root>/carries/<job>/`` via
    ``checkpointer.save_carry`` (itself tmp-rename atomic); the journal
    only holds the pointer (iteration, slice index).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Set

from .admission import (AdmissionDecision, JobProfile,
                        RecoveryConformanceError)

__all__ = ["JobStore", "StoreState", "JobRecord", "CompactionPolicy",
           "RecoveryConformanceError"]

_JOURNAL = "journal.jsonl"
_SNAPSHOT = "snapshot.json"
_FORMAT_VERSION = 1


@dataclass(frozen=True)
class CompactionPolicy:
    """Opportunistic journal-compaction triggers: after any append, the
    journal is folded into the snapshot when it exceeds ``max_bytes``,
    ``max_records`` appended since the last compaction, or ``max_age_s``
    since the first post-compaction append.  ``None`` disables a
    trigger; a policy with every trigger ``None`` never auto-compacts
    (equivalent to not attaching one)."""
    max_bytes: Optional[int] = 1 << 20       # 1 MiB
    max_records: Optional[int] = None
    max_age_s: Optional[float] = None

    def due(self, size: int, records: int, age_s: float) -> bool:
        return ((self.max_bytes is not None and size >= self.max_bytes)
                or (self.max_records is not None
                    and records >= self.max_records)
                or (self.max_age_s is not None and records > 0
                    and age_s >= self.max_age_s))


@dataclass
class JobRecord:
    """Folded state of one live (admitted, unreleased) job."""
    profile: dict
    decision: dict
    device: Optional[int] = None
    workload: Optional[dict] = None      # {"name": ..., "kwargs": {...}}
    n_iterations: int = 1
    done_iterations: int = 0
    # latest mid-segment carry pointer: {"iteration": i, "slice": s},
    # None when the job is between iterations (or never sliced)
    carry: Optional[dict] = None

    @property
    def name(self) -> str:
        return self.profile["name"]

    def to_json(self) -> dict:
        return {"profile": self.profile, "decision": self.decision,
                "device": self.device, "workload": self.workload,
                "n_iterations": self.n_iterations,
                "done_iterations": self.done_iterations,
                "carry": self.carry}

    @classmethod
    def from_json(cls, d: Mapping) -> "JobRecord":
        return cls(**dict(d))


@dataclass
class StoreState:
    """The folded view of snapshot + journal."""
    config: Optional[dict] = None        # AdmissionController.export_config
    cluster: Optional[dict] = None       # ClusterExecutor shape (n_devices…)
    jobs: Dict[str, JobRecord] = field(default_factory=dict)  # insertion-
    # ordered = admission-ordered (dicts preserve insertion order; a
    # re-admission after fail-over re-inserts at the end, so the order
    # stays the order decisions were actually taken in)
    refusals: List[dict] = field(default_factory=list)
    resumes: List[dict] = field(default_factory=list)
    # fault-containment state (DESIGN.md §10)
    epoch: int = 0                       # current binding epoch
    failed_devices: Set[int] = field(default_factory=set)
    shed: Dict[str, JobRecord] = field(default_factory=dict)  # evicted
    # best-effort jobs awaiting resumption (carry/done_iterations kept)
    # jobs displaced by a device failure whose re-admission outcome has
    # not been journaled yet — empty in any quiescent journal (the
    # no-silent-job-loss audit the chaos suite replays)
    displaced: Dict[str, JobRecord] = field(default_factory=dict)
    requests: Dict[str, dict] = field(default_factory=dict)  # request_id
    # -> journaled decision (the idempotent-submission dedup table)

    def admission_entries(self) -> List[dict]:
        """``AdmissionController.rebuild`` input: the live jobs, in
        admission order."""
        return [{"profile": r.profile, "decision": r.decision}
                for r in self.jobs.values()]

    def unaccounted(self) -> List[str]:
        """Names whose journaled lifecycle is dangling: displaced by a
        fail-over with no re-admission/refusal journaled.  Non-empty
        means a job was silently lost — the invariant the chaos suite
        asserts is empty after every failure scenario."""
        return sorted(self.displaced)


class JobStore:
    """Append-only journal + atomic snapshot of the scheduling state."""

    def __init__(self, root: str, *, sync: bool = True,
                 auto_compact: Optional[CompactionPolicy] = None):
        self.root = root
        self.sync = sync
        self.auto_compact = auto_compact
        self.compactions = 0              # auto+manual, for tests/stats
        os.makedirs(root, exist_ok=True)
        os.makedirs(self.carries_root, exist_ok=True)
        self._lock = threading.Lock()
        self._compact_lock = threading.Lock()  # serializes compactions
        self._journal_path = os.path.join(root, _JOURNAL)
        self._fh = open(self._journal_path, "a", encoding="utf-8")
        # pre-existing journal lines count toward the records trigger
        self._records = self._count_journal_lines()
        self._first_append_t = (time.monotonic()
                                if self._records else None)

    def _count_journal_lines(self) -> int:
        try:
            with open(self._journal_path, encoding="utf-8") as f:
                return sum(1 for line in f if line.strip())
        except OSError:
            return 0

    # ------------------------------------------------------------------
    # paths
    # ------------------------------------------------------------------
    @property
    def carries_root(self) -> str:
        return os.path.join(self.root, "carries")

    def carry_dir(self, job: str) -> str:
        """Checkpoint directory for one job's carries; pass to
        ``checkpointer.save_carry(dir, label=job, ...)``."""
        return os.path.join(self.carries_root, job)

    # ------------------------------------------------------------------
    # journal writes
    # ------------------------------------------------------------------
    def _append(self, rec: dict) -> None:
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()
            if self.sync:
                os.fsync(self._fh.fileno())
            self._records += 1
            if self._first_append_t is None:
                self._first_append_t = time.monotonic()
        self._maybe_compact()

    def _maybe_compact(self) -> None:
        """Opportunistic compaction: run the existing ``compact`` op
        when the attached :class:`CompactionPolicy` says the journal is
        due.  Called outside the append lock (``compact`` takes it);
        racing appenders may both see the trigger — ``compact`` itself
        is concurrency-safe and the second run folds a near-empty
        journal, which is harmless."""
        pol = self.auto_compact
        if pol is None:
            return
        with self._lock:
            try:
                size = os.path.getsize(self._journal_path)
            except OSError:
                return
            records = self._records
            age = (time.monotonic() - self._first_append_t
                   if self._first_append_t is not None else 0.0)
        if pol.due(size, records, age):
            self.compact()

    def record_config(self, admission_config: Mapping,
                      cluster: Optional[Mapping] = None) -> None:
        """Platform model (admission config + cluster shape): recovery
        must rebuild an identically configured gatekeeper."""
        self._append({"rec": "config", "v": _FORMAT_VERSION,
                      "admission": dict(admission_config),
                      "cluster": dict(cluster or {})})

    def record_decision(self, prof: JobProfile, decision: Mapping, *,
                        device: Optional[int] = None,
                        workload: Optional[Mapping] = None,
                        n_iterations: int = 1,
                        done_iterations: int = 0,
                        epoch: Optional[int] = None,
                        request_id: Optional[str] = None) -> None:
        """One admission decision, verbatim (accepted or refused).
        Accepted decisions fold into live-job state on replay; refusals
        are kept as an audit trail only.  ``epoch`` tags a decision
        taken inside a fail-over binding epoch; ``request_id`` is the
        client's idempotency token (the daemon dedups resubmissions by
        it); ``done_iterations`` carries a resumed/re-admitted job's
        progress across the decision."""
        dec = (decision.journal_form()
               if isinstance(decision, AdmissionDecision)
               else {k: v for k, v in dict(decision).items()
                     if k != "job"})
        rec = {"rec": "decision", "profile": prof.to_dict(),
               "decision": dec, "device": device,
               "workload": dict(workload) if workload else None,
               "n_iterations": n_iterations}
        if done_iterations:
            rec["done_iterations"] = done_iterations
        if epoch is not None:
            rec["epoch"] = epoch
        if request_id is not None:
            rec["request_id"] = request_id
        self._append(rec)

    def record_release(self, name: str) -> None:
        self._append({"rec": "release", "job": name})

    def record_failover(self, device: int, epoch: int,
                        reason: str = "") -> None:
        """A device was declared failed and binding epoch ``epoch``
        opened: on replay, every live job bound to that device becomes
        *displaced* until a follow-up decision record (re-admission or
        refusal) settles it — the no-silent-job-loss ledger."""
        self._append({"rec": "failover", "device": device,
                      "epoch": epoch, "reason": reason})

    def record_shed(self, name: str, reason: str = "") -> None:
        """A best-effort job was evicted by the overload degradation
        ladder; its folded record (carry pointer, done iterations)
        moves to the shed set, from which a later re-admission decision
        resumes it."""
        self._append({"rec": "shed", "job": name, "reason": reason})

    def record_carry(self, name: str, iteration: int,
                     slice_idx: int) -> None:
        """Pointer to the latest checkpointed carry (the pytree itself
        went through ``checkpointer.save_carry(self.carry_dir(name),
        label=name, slice_idx=...)``)."""
        self._append({"rec": "carry", "job": name,
                      "iteration": iteration, "slice": slice_idx})

    def record_iteration_done(self, name: str, iteration: int) -> None:
        """An iteration finalized: its carry pointer is dead (resume
        restarts the *next* iteration from scratch)."""
        self._append({"rec": "iter_done", "job": name,
                      "iteration": iteration})

    def record_resume(self, name: str, iteration: int,
                      slice_idx: int) -> None:
        """Recovery resumed this job mid-segment (audit record the
        kill-and-recover suite asserts on)."""
        self._append({"rec": "resume", "job": name,
                      "iteration": iteration, "slice": slice_idx})

    # ------------------------------------------------------------------
    # replay
    # ------------------------------------------------------------------
    @staticmethod
    def _apply(state: StoreState, rec: Mapping) -> None:
        kind = rec.get("rec")
        if kind == "config":
            state.config = rec["admission"]
            state.cluster = rec.get("cluster") or None
        elif kind == "decision":
            name = rec["profile"]["name"]
            rid = rec.get("request_id")
            if rid is not None:
                state.requests[rid] = {
                    "job": name,
                    "admitted": bool(rec["decision"].get("admitted")),
                    "decision": rec["decision"]}
            if rec["decision"].get("admitted"):
                # idempotent fold: compaction may crash between the
                # snapshot replace and the journal replace, re-applying
                # the same record — last write wins, state identical.
                # pop-then-insert so dict insertion order stays the
                # order decisions were actually taken in (a fail-over
                # re-admission moves the job to the end, matching the
                # fresh decision record rebuild() will replay)
                state.jobs.pop(name, None)
                state.jobs[name] = JobRecord(
                    profile=rec["profile"], decision=rec["decision"],
                    device=rec.get("device"),
                    workload=rec.get("workload"),
                    n_iterations=rec.get("n_iterations", 1),
                    done_iterations=rec.get("done_iterations", 0))
                # a decision settles any dangling displaced/shed entry
                state.displaced.pop(name, None)
                state.shed.pop(name, None)
            else:
                state.refusals.append(rec)
                # an explicit refusal also settles a displaced job: it
                # was not silently lost, the platform refused it on the
                # record (the job is gone, but accounted for)
                state.displaced.pop(name, None)
        elif kind == "release":
            state.jobs.pop(rec["job"], None)
            state.shed.pop(rec["job"], None)
        elif kind == "failover":
            state.epoch = rec["epoch"]
            state.failed_devices.add(rec["device"])
            for name in [n for n, r in state.jobs.items()
                         if r.device == rec["device"]]:
                state.displaced[name] = state.jobs.pop(name)
        elif kind == "shed":
            job = state.jobs.pop(rec["job"], None)
            if job is not None:
                state.shed[rec["job"]] = job
        elif kind == "carry":
            job = state.jobs.get(rec["job"])
            if job is not None:
                job.carry = {"iteration": rec["iteration"],
                             "slice": rec["slice"]}
        elif kind == "iter_done":
            job = state.jobs.get(rec["job"])
            if job is not None:
                job.carry = None
                job.done_iterations = max(job.done_iterations,
                                          rec["iteration"] + 1)
        elif kind == "resume":
            state.resumes.append(dict(rec))
        elif kind == "snapshot_state":
            # snapshot.json payload replayed through the same fold
            state.config = rec.get("config")
            state.cluster = rec.get("cluster")
            state.jobs = {name: JobRecord.from_json(j)
                          for name, j in rec.get("jobs", {}).items()}
            state.epoch = rec.get("epoch", 0)
            state.failed_devices = set(rec.get("failed_devices", []))
            state.shed = {name: JobRecord.from_json(j)
                          for name, j in rec.get("shed", {}).items()}
            state.displaced = {
                name: JobRecord.from_json(j)
                for name, j in rec.get("displaced", {}).items()}
            state.requests = dict(rec.get("requests", {}))
        # unknown record kinds are skipped: an old daemon must be able
        # to read a journal a newer one appended audit records to

    def load(self) -> StoreState:
        """Fold snapshot + journal into the current state.

        Taken under the store lock so a concurrent ``compact`` cannot
        slide the journal out from under the fold between the snapshot
        read and the journal read (old snapshot + truncated journal
        would silently drop the compacted records)."""
        with self._lock:
            return self._load_unlocked()

    def _load_unlocked(self) -> StoreState:
        state = StoreState()
        snap_path = os.path.join(self.root, _SNAPSHOT)
        if os.path.exists(snap_path):
            with open(snap_path, encoding="utf-8") as f:
                snap = json.load(f)
            self._apply(state, dict(snap, rec="snapshot_state"))
        if os.path.exists(self._journal_path):
            with open(self._journal_path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        # a torn final line (crash mid-append) is not
                        # state: everything before it was fsync'd
                        continue
                    self._apply(state, rec)
        return state

    # ------------------------------------------------------------------
    # compaction
    # ------------------------------------------------------------------
    def compact(self) -> StoreState:
        """Fold the journal into ``snapshot.json`` and truncate it.

        Both steps are atomic replaces; the crash window between them
        (snapshot new, journal old) double-applies records on the next
        load, which the idempotent fold absorbs.

        The whole fold+swap runs under the store lock: an earlier
        version folded the journal *outside* the lock, so a record
        appended between the fold and the journal truncation was
        silently dropped (caught by
        tests/test_store.py::test_compact_concurrent_appends_lose_nothing).
        Appends now block for the duration of a compaction — bounded by
        snapshot size, and the auto-compaction policy keeps journals
        small — in exchange for never losing a journaled record."""
        with self._compact_lock, self._lock:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            state = self._load_unlocked()
            snap = {"v": _FORMAT_VERSION, "config": state.config,
                    "cluster": state.cluster,
                    "jobs": {name: r.to_json()
                             for name, r in state.jobs.items()},
                    "epoch": state.epoch,
                    "failed_devices": sorted(state.failed_devices),
                    "shed": {name: r.to_json()
                             for name, r in state.shed.items()},
                    "displaced": {name: r.to_json()
                                  for name, r in state.displaced.items()},
                    "requests": state.requests}
            snap_path = os.path.join(self.root, _SNAPSHOT)
            tmp = snap_path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump(snap, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, snap_path)
            self._fh.close()
            tmp_j = self._journal_path + ".tmp"
            with open(tmp_j, "w", encoding="utf-8") as f:
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp_j, self._journal_path)
            self._fh = open(self._journal_path, "a", encoding="utf-8")
            self._records = 0
            self._first_append_t = None
            self.compactions += 1
        return state

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                self._fh.close()

    def __enter__(self) -> "JobStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
