"""Journaled job store: the durable face of the cluster runtime.

The store makes an admitted job's guarantee survive the process that
admitted it (DESIGN.md §9).  It records, per job: the submitted
``JobProfile``, the :class:`~repro.sched.admission.AdmissionDecision`
with its WCRT evidence (journaled verbatim — the decision dict *is* the
JSON record), the immutable device binding chosen by the
admit→place→bind transaction, the workload spec (a registry name +
kwargs the daemon can reconstruct the job body from), and the latest
checkpointed carry pointer of a sliced job mid-segment.

Durability discipline:

  * **append-only journal** (``journal.jsonl``): one JSON record per
    line, flushed + fsync'd per append.  The journal order of accepted
    decisions IS the admission order — ``ClusterExecutor`` appends
    inside its transaction lock — which is what lets recovery re-run
    admission over the journaled taskset and assert it reproduces the
    recorded decisions (`AdmissionController.rebuild`).
  * **atomic snapshot compaction** (``snapshot.json``): the folded
    state is written to a temp file and ``os.replace``'d into place
    (the same tmp-rename discipline as ``checkpointer.save``), then the
    journal is atomically replaced by an empty one.  A crash between
    the two replaces leaves snapshot *and* old journal — replay is
    idempotent (records fold by job name), so the double-apply is
    harmless.
  * **carries** live under ``<root>/carries/<job>/`` via
    ``checkpointer.save_carry`` (itself tmp-rename atomic); the journal
    only holds the pointer (iteration, slice index).
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional

from .admission import (AdmissionDecision, JobProfile,
                        RecoveryConformanceError)

__all__ = ["JobStore", "StoreState", "JobRecord",
           "RecoveryConformanceError"]

_JOURNAL = "journal.jsonl"
_SNAPSHOT = "snapshot.json"
_FORMAT_VERSION = 1


@dataclass
class JobRecord:
    """Folded state of one live (admitted, unreleased) job."""
    profile: dict
    decision: dict
    device: Optional[int] = None
    workload: Optional[dict] = None      # {"name": ..., "kwargs": {...}}
    n_iterations: int = 1
    done_iterations: int = 0
    # latest mid-segment carry pointer: {"iteration": i, "slice": s},
    # None when the job is between iterations (or never sliced)
    carry: Optional[dict] = None

    @property
    def name(self) -> str:
        return self.profile["name"]

    def to_json(self) -> dict:
        return {"profile": self.profile, "decision": self.decision,
                "device": self.device, "workload": self.workload,
                "n_iterations": self.n_iterations,
                "done_iterations": self.done_iterations,
                "carry": self.carry}

    @classmethod
    def from_json(cls, d: Mapping) -> "JobRecord":
        return cls(**dict(d))


@dataclass
class StoreState:
    """The folded view of snapshot + journal."""
    config: Optional[dict] = None        # AdmissionController.export_config
    cluster: Optional[dict] = None       # ClusterExecutor shape (n_devices…)
    jobs: Dict[str, JobRecord] = field(default_factory=dict)  # insertion-
    # ordered = admission-ordered (dicts preserve insertion order)
    refusals: List[dict] = field(default_factory=list)
    resumes: List[dict] = field(default_factory=list)

    def admission_entries(self) -> List[dict]:
        """``AdmissionController.rebuild`` input: the live jobs, in
        admission order."""
        return [{"profile": r.profile, "decision": r.decision}
                for r in self.jobs.values()]


class JobStore:
    """Append-only journal + atomic snapshot of the scheduling state."""

    def __init__(self, root: str, *, sync: bool = True):
        self.root = root
        self.sync = sync
        os.makedirs(root, exist_ok=True)
        os.makedirs(self.carries_root, exist_ok=True)
        self._lock = threading.Lock()
        self._journal_path = os.path.join(root, _JOURNAL)
        self._fh = open(self._journal_path, "a", encoding="utf-8")

    # ------------------------------------------------------------------
    # paths
    # ------------------------------------------------------------------
    @property
    def carries_root(self) -> str:
        return os.path.join(self.root, "carries")

    def carry_dir(self, job: str) -> str:
        """Checkpoint directory for one job's carries; pass to
        ``checkpointer.save_carry(dir, label=job, ...)``."""
        return os.path.join(self.carries_root, job)

    # ------------------------------------------------------------------
    # journal writes
    # ------------------------------------------------------------------
    def _append(self, rec: dict) -> None:
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            self._fh.write(line + "\n")
            self._fh.flush()
            if self.sync:
                os.fsync(self._fh.fileno())

    def record_config(self, admission_config: Mapping,
                      cluster: Optional[Mapping] = None) -> None:
        """Platform model (admission config + cluster shape): recovery
        must rebuild an identically configured gatekeeper."""
        self._append({"rec": "config", "v": _FORMAT_VERSION,
                      "admission": dict(admission_config),
                      "cluster": dict(cluster or {})})

    def record_decision(self, prof: JobProfile, decision: Mapping, *,
                        device: Optional[int] = None,
                        workload: Optional[Mapping] = None,
                        n_iterations: int = 1) -> None:
        """One admission decision, verbatim (accepted or refused).
        Accepted decisions fold into live-job state on replay; refusals
        are kept as an audit trail only."""
        dec = (decision.journal_form()
               if isinstance(decision, AdmissionDecision)
               else {k: v for k, v in dict(decision).items()
                     if k != "job"})
        self._append({"rec": "decision", "profile": prof.to_dict(),
                      "decision": dec, "device": device,
                      "workload": dict(workload) if workload else None,
                      "n_iterations": n_iterations})

    def record_release(self, name: str) -> None:
        self._append({"rec": "release", "job": name})

    def record_carry(self, name: str, iteration: int,
                     slice_idx: int) -> None:
        """Pointer to the latest checkpointed carry (the pytree itself
        went through ``checkpointer.save_carry(self.carry_dir(name),
        label=name, slice_idx=...)``)."""
        self._append({"rec": "carry", "job": name,
                      "iteration": iteration, "slice": slice_idx})

    def record_iteration_done(self, name: str, iteration: int) -> None:
        """An iteration finalized: its carry pointer is dead (resume
        restarts the *next* iteration from scratch)."""
        self._append({"rec": "iter_done", "job": name,
                      "iteration": iteration})

    def record_resume(self, name: str, iteration: int,
                      slice_idx: int) -> None:
        """Recovery resumed this job mid-segment (audit record the
        kill-and-recover suite asserts on)."""
        self._append({"rec": "resume", "job": name,
                      "iteration": iteration, "slice": slice_idx})

    # ------------------------------------------------------------------
    # replay
    # ------------------------------------------------------------------
    @staticmethod
    def _apply(state: StoreState, rec: Mapping) -> None:
        kind = rec.get("rec")
        if kind == "config":
            state.config = rec["admission"]
            state.cluster = rec.get("cluster") or None
        elif kind == "decision":
            if rec["decision"].get("admitted"):
                name = rec["profile"]["name"]
                # idempotent fold: compaction may crash between the
                # snapshot replace and the journal replace, re-applying
                # the same record — last write wins, state identical
                state.jobs[name] = JobRecord(
                    profile=rec["profile"], decision=rec["decision"],
                    device=rec.get("device"),
                    workload=rec.get("workload"),
                    n_iterations=rec.get("n_iterations", 1))
            else:
                state.refusals.append(rec)
        elif kind == "release":
            state.jobs.pop(rec["job"], None)
        elif kind == "carry":
            job = state.jobs.get(rec["job"])
            if job is not None:
                job.carry = {"iteration": rec["iteration"],
                             "slice": rec["slice"]}
        elif kind == "iter_done":
            job = state.jobs.get(rec["job"])
            if job is not None:
                job.carry = None
                job.done_iterations = max(job.done_iterations,
                                          rec["iteration"] + 1)
        elif kind == "resume":
            state.resumes.append(dict(rec))
        elif kind == "snapshot_state":
            # snapshot.json payload replayed through the same fold
            state.config = rec.get("config")
            state.cluster = rec.get("cluster")
            state.jobs = {name: JobRecord.from_json(j)
                          for name, j in rec.get("jobs", {}).items()}
        # unknown record kinds are skipped: an old daemon must be able
        # to read a journal a newer one appended audit records to

    def load(self) -> StoreState:
        """Fold snapshot + journal into the current state."""
        state = StoreState()
        snap_path = os.path.join(self.root, _SNAPSHOT)
        if os.path.exists(snap_path):
            with open(snap_path, encoding="utf-8") as f:
                snap = json.load(f)
            self._apply(state, dict(snap, rec="snapshot_state"))
        if os.path.exists(self._journal_path):
            with open(self._journal_path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        # a torn final line (crash mid-append) is not
                        # state: everything before it was fsync'd
                        continue
                    self._apply(state, rec)
        return state

    # ------------------------------------------------------------------
    # compaction
    # ------------------------------------------------------------------
    def compact(self) -> StoreState:
        """Fold the journal into ``snapshot.json`` and truncate it.

        Both steps are atomic replaces; the crash window between them
        (snapshot new, journal old) double-applies records on the next
        load, which the idempotent fold absorbs."""
        with self._lock:
            self._fh.flush()
            os.fsync(self._fh.fileno())
        state = self.load()
        snap = {"v": _FORMAT_VERSION, "config": state.config,
                "cluster": state.cluster,
                "jobs": {name: r.to_json()
                         for name, r in state.jobs.items()}}
        snap_path = os.path.join(self.root, _SNAPSHOT)
        tmp = snap_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(snap, f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, snap_path)
        with self._lock:
            self._fh.close()
            tmp_j = self._journal_path + ".tmp"
            with open(tmp_j, "w", encoding="utf-8") as f:
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp_j, self._journal_path)
            self._fh = open(self._journal_path, "a", encoding="utf-8")
        return state

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                self._fh.close()

    def __enter__(self) -> "JobStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
