"""Daemon supervisor: watchdog + restart-through-recovery (DESIGN.md §10).

The scheduling daemon's durability story (journal → rebuild → rebind →
resume) only pays off if *something* restarts the daemon after a crash.
This supervisor is that something: it spawns ``repro.sched.daemon`` as a
child process and watches two signals —

  * **exit** (``waitpid``): the child died (crash, SIGKILL, OOM) —
    restart it with jittered exponential backoff; the restart goes
    through the full recovery path, so the admitted jobs come back with
    their journaled guarantees re-proven;
  * **heartbeat staleness**: the daemon touches its ``--heartbeat-file``
    every loop turn; a live pid with a stale beacon is a *hung* daemon
    (deadlock, stuck runtime) that ``waitpid`` alone cannot see — the
    supervisor SIGKILLs it and lets the exit path restart it.

Crucially, the supervisor must not *mask* a daemon that cannot come up —
above all :class:`~repro.sched.admission.RecoveryConformanceError`, the
recovery path's refusal to serve guarantees it can no longer prove.  A
child that keeps dying within ``min_uptime_s`` is counted as a *fast
failure*; after ``max_restarts`` consecutive fast failures the
supervisor gives up and surfaces the tail of the daemon's log (where the
conformance traceback lives) instead of thrashing forever.

Run it::

    PYTHONPATH=src python -m repro.sched.supervisor \\
        --store /var/lib/schedd --socket /run/schedd.sock \\
        -- --n-devices 2 --health
"""
from __future__ import annotations

import json
import os
import random
import signal
import subprocess
import sys
import threading
import time
from typing import IO, List, Optional, Sequence, Tuple

__all__ = ["Supervisor"]


class Supervisor:
    """Spawn + watch one daemon process; restart through recovery.

    ``cmd`` is the full child argv (tests point it at a script of their
    own; the CLI builds the ``repro.sched.daemon`` invocation).  All
    thresholds are seconds.  ``run()`` blocks until ``stop()`` or
    give-up; ``start()`` runs it on a thread."""

    def __init__(self, cmd: Sequence[str], *,
                 heartbeat_file: Optional[str] = None,
                 heartbeat_timeout_s: float = 10.0,
                 poll_s: float = 0.2,
                 restart_backoff_s: float = 0.5,
                 max_backoff_s: float = 10.0,
                 min_uptime_s: float = 3.0,
                 max_restarts: int = 5,
                 log_path: Optional[str] = None,
                 rng: Optional[random.Random] = None):
        self.cmd = list(cmd)
        self.heartbeat_file = heartbeat_file
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.poll_s = poll_s
        self.restart_backoff_s = restart_backoff_s
        self.max_backoff_s = max_backoff_s
        self.min_uptime_s = min_uptime_s
        self.max_restarts = max_restarts
        self.log_path = log_path
        self.restarts = 0
        self.gave_up = False
        self.give_up_reason = ""
        # (monotonic time, event, detail) audit trail the tests assert on
        self.events: List[Tuple[float, str, str]] = []
        self._rng = rng or random.Random()
        self._fast_failures = 0
        self._proc: Optional[subprocess.Popen] = None
        self._started_at = 0.0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def pid(self) -> Optional[int]:
        p = self._proc
        return p.pid if p is not None and p.poll() is None else None

    def _event(self, event: str, detail: str = "") -> None:
        self.events.append((time.monotonic(), event, detail))

    def _open_log(self) -> Optional[IO]:
        if self.log_path is None:
            return None
        return open(self.log_path, "ab")

    def _spawn(self) -> None:
        log = self._open_log()
        try:
            self._proc = subprocess.Popen(
                self.cmd,
                stdout=log if log is not None else subprocess.DEVNULL,
                stderr=subprocess.STDOUT if log is not None
                else subprocess.DEVNULL)
        finally:
            if log is not None:
                log.close()   # the child holds its own descriptor
        self._started_at = time.monotonic()
        self._event("spawn", f"pid={self._proc.pid}")

    def _log_tail(self, n: int = 40) -> str:
        if self.log_path is None or not os.path.exists(self.log_path):
            return "(no daemon log captured — pass log_path)"
        try:
            with open(self.log_path, "rb") as f:
                lines = f.read().decode(errors="replace").splitlines()
            return "\n".join(lines[-n:])
        except OSError as e:
            return f"(daemon log unreadable: {e})"

    def _heartbeat_stale(self) -> Optional[float]:
        """Age (s) of a stale heartbeat, or ``None`` when fresh/absent.
        Before the first beacon appears, the child's own uptime stands
        in — a daemon that never beats at all is just as hung."""
        if self.heartbeat_file is None:
            return None
        try:
            with open(self.heartbeat_file, encoding="utf-8") as f:
                age = time.time() - float(json.load(f)["t"])
        except (OSError, ValueError, KeyError):
            age = time.monotonic() - self._started_at
        return age if age > self.heartbeat_timeout_s else None

    # ------------------------------------------------------------------
    def run(self) -> None:
        if self._proc is None:
            self._spawn()
        while not self._stop.is_set():
            rc = self._proc.poll()
            if rc is not None:
                uptime = time.monotonic() - self._started_at
                self._event("exit", f"rc={rc} uptime={uptime:.2f}s")
                if uptime < self.min_uptime_s:
                    self._fast_failures += 1
                else:
                    self._fast_failures = 0
                if self._fast_failures > self.max_restarts:
                    # the daemon cannot come up — a RecoveryConformance
                    # failure, a bad config, a corrupt journal.  Give
                    # up LOUDLY: the log tail carries the traceback the
                    # operator (and the chaos suite) must see
                    self.gave_up = True
                    self.give_up_reason = (
                        f"{self._fast_failures} consecutive exits within "
                        f"min_uptime_s={self.min_uptime_s:g} — refusing "
                        f"to keep restarting a daemon that cannot come "
                        f"up.  Last daemon output:\n{self._log_tail()}")
                    self._event("give_up", self.give_up_reason)
                    return
                delay = min(self.restart_backoff_s
                            * (2 ** max(self._fast_failures - 1, 0)),
                            self.max_backoff_s)
                if self._stop.wait(delay * self._rng.uniform(0.5, 1.5)):
                    return
                self.restarts += 1
                self._event("restart", f"#{self.restarts}")
                self._spawn()
                continue
            stale = self._heartbeat_stale()
            if stale is not None:
                # alive pid, dead heartbeat: a hung daemon.  SIGKILL —
                # SIGTERM would be absorbed by the hang — and let the
                # exit branch restart it through recovery
                self._event("hang_kill",
                            f"heartbeat stale {stale:.2f}s "
                            f"(timeout {self.heartbeat_timeout_s:g}s)")
                try:
                    self._proc.kill()
                except OSError:
                    pass
            self._stop.wait(self.poll_s)
        self._terminate_child()

    def start(self) -> "Supervisor":
        self._spawn()
        self._thread = threading.Thread(target=self.run, daemon=True,
                                        name="sched-supervisor")
        self._thread.start()
        return self

    def _terminate_child(self) -> None:
        p = self._proc
        if p is None or p.poll() is not None:
            return
        try:
            p.terminate()
            try:
                p.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5.0)
        except OSError:
            pass

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._terminate_child()

    def __enter__(self) -> "Supervisor":
        return self if self._thread is not None else self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.sched.supervisor",
        description="watchdog + auto-restart for the scheduling daemon "
                    "(restarts go through the journal recovery path); "
                    "daemon flags go after '--'")
    ap.add_argument("--store", required=True)
    ap.add_argument("--socket", default=None)
    ap.add_argument("--heartbeat-file", default=None,
                    help="default: <store>/heartbeat.json")
    ap.add_argument("--heartbeat-timeout-s", type=float, default=10.0)
    ap.add_argument("--min-uptime-s", type=float, default=3.0)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--restart-backoff-s", type=float, default=0.5)
    ap.add_argument("--log", default=None,
                    help="daemon stdout/stderr log "
                         "(default: <store>/daemon.log)")
    ap.add_argument("daemon_args", nargs="*",
                    help="extra repro.sched.daemon flags (after '--')")
    args = ap.parse_args(argv)

    hb = args.heartbeat_file or os.path.join(args.store, "heartbeat.json")
    log = args.log or os.path.join(args.store, "daemon.log")
    os.makedirs(args.store, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.sched.daemon",
           "--store", args.store, "--heartbeat-file", hb]
    if args.socket:
        cmd += ["--socket", args.socket]
    cmd += list(args.daemon_args)

    sup = Supervisor(cmd, heartbeat_file=hb,
                     heartbeat_timeout_s=args.heartbeat_timeout_s,
                     min_uptime_s=args.min_uptime_s,
                     max_restarts=args.max_restarts,
                     restart_backoff_s=args.restart_backoff_s,
                     log_path=log)

    def _term(signum, frame):
        sup._stop.set()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    print(f"supervisor ready pid={os.getpid()} cmd={' '.join(cmd)} "
          f"heartbeat={hb} log={log}", flush=True)
    sup.run()
    if sup.gave_up:
        print(f"supervisor gave up: {sup.give_up_reason}",
              file=sys.stderr, flush=True)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
