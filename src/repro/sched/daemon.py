"""Durable scheduling daemon: the persistent face of ``ClusterExecutor``.

The paper's admission guarantees are only as durable as the process that
holds them; this daemon makes them survive it (DESIGN.md §9).  It owns
the cluster, journals every admit→place→bind transaction through
:class:`~repro.sched.store.JobStore`, accepts submissions over a unix
socket (``repro.sched.client`` / the ``SchedClient`` facade), and on
startup runs the recovery path:

  1. **rebuild** — re-run admission over the journaled taskset in its
     recorded order and assert it reproduces the recorded decisions
     (``AdmissionController.rebuild(conform=True)``); a mismatch raises
     :class:`RecoveryConformanceError` and the daemon refuses to come up
     — the durable analogue of ``tests/conformance.py``'s
     live↔simulated decision identity;
  2. **rebind** — every recovered job is re-bound to its journaled
     device (the immutable binding survives the crash, so the
     migration-free invariant holds *across restarts*);
  3. **resume** — a job that was mid-segment restarts from its latest
     checkpointed carry at the journaled slice index
     (``checkpointer.latest_carry``), not from scratch; remaining
     iterations then run normally.

Run it:

    PYTHONPATH=src python -m repro.sched.daemon \
        --store /var/lib/schedd --socket /run/schedd.sock --n-devices 2

and talk to it with ``python -m repro.sched.client --socket ...`` or
``repro.sched.connect("/run/schedd.sock")``.
"""
from __future__ import annotations

import json
import os
import signal
import socket as socketlib
import threading
import time
from typing import Mapping, Optional

from .admission import (AdmissionController, AdmissionDecision,
                        JobProfile, RecoveryConformanceError)
from .cluster import ClusterExecutor
from .elastic import ShedPolicy
from .fault import HealthConfig
from .job import RTJob
from .store import CompactionPolicy, JobRecord, JobStore
from .workloads import make_body, normalize_spec

__all__ = ["SchedDaemon", "RecoveryConformanceError"]


class SchedDaemon:
    """Owns the cluster + store; serves the submission API on a unix
    socket.  Construction runs the full recovery path; ``start()``
    spawns the acceptor thread (``serve_forever()`` runs it inline)."""

    def __init__(self, store_dir: str, socket_path: Optional[str] = None,
                 *, n_devices: int = 1, policy="ioctl",
                 wait_mode: str = "suspend", n_cpus: int = 4,
                 epsilon_ms: float = 1.0, placement: str = "pinned",
                 headroom: float = 1.0, try_gpu_priorities: bool = True,
                 checkpoint_every: int = 1, conform: bool = True,
                 resume_jobs: bool = True,
                 health: Optional[HealthConfig] = None,
                 shed_policy: Optional[ShedPolicy] = None,
                 heartbeat_file: Optional[str] = None,
                 auto_compact: Optional[CompactionPolicy] = None):
        self.socket_path = socket_path or os.path.join(store_dir, "sock")
        self.checkpoint_every = checkpoint_every
        # liveness beacon for sched.supervisor: touched every loop turn
        # of serve_forever; a stale mtime means a hung (not just dead)
        # daemon, which a poll-based waitpid watchdog cannot see
        self.heartbeat_file = heartbeat_file
        self.store = JobStore(store_dir, auto_compact=auto_compact)
        state = self.store.load()
        self.recovery = {"recovered": [], "resumed": {},
                         "conformance": None}
        admission = None
        if state.config is not None:
            # the journaled platform model wins: a daemon must come back
            # AS the platform whose guarantees it journaled — a config
            # drift would invalidate every recorded WCRT
            shape = state.cluster or {}
            n_devices = shape.get("n_devices", state.config["n_devices"])
            policy = shape.get("policy", policy)
            placement = shape.get("placement", placement)
            wait_mode = state.config["wait_mode"]
            n_cpus = state.config["n_cpus"]
            epsilon_ms = state.config["epsilon_ms"]
            headroom = state.config["headroom"]
            try_gpu_priorities = state.config["try_gpu_priorities"]
            # decision-conformance on recovery: re-run admission over
            # the journaled taskset, in order, and require identity
            admission = AdmissionController.rebuild(
                state.config, state.admission_entries(), conform=conform)
            self.recovery["conformance"] = ("checked" if conform
                                            else "skipped")
            self.recovery["recovered"] = [r.name
                                          for r in state.jobs.values()]
        self.cluster = ClusterExecutor(
            n_devices=n_devices, policy=policy, wait_mode=wait_mode,
            n_cpus=n_cpus, epsilon_ms=epsilon_ms, placement=placement,
            try_gpu_priorities=try_gpu_priorities, admission=admission,
            store=self.store, health=health, shed_policy=shed_policy)
        if state.epoch or state.failed_devices:
            # a device failed in a previous life stays failed: the
            # journaled re-admissions were proven against the surviving
            # platform, so recovery must come back AS that platform
            self.cluster.restore_fault_state(state.epoch,
                                             state.failed_devices)
            self.recovery["epoch"] = state.epoch
            self.recovery["failed_devices"] = sorted(state.failed_devices)
        # idempotent-submission dedup table, rebuilt from the journal:
        # a client retrying across a daemon restart gets the journaled
        # decision back instead of a double admission
        self._requests = dict(state.requests)
        if state.config is None:
            # the cluster-built controller defaults headroom=1.0; apply
            # the daemon's before anything is admitted or journaled
            self.cluster.admission.headroom = headroom
            self.store.record_config(
                self.cluster.admission.export_config(),
                {"n_devices": n_devices, "policy": policy,
                 "placement": placement})
        self._state = state
        if resume_jobs:
            for rec in state.jobs.values():
                self._resume(rec)
            # a crash mid-fail-over leaves jobs on the displaced ledger
            # (failover journaled, outcome not): settle every one now —
            # re-admitted onto a survivor or explicitly refused on the
            # record — so state.unaccounted() drains to [] and no job
            # is silently lost
            for rec in list(state.displaced.values()):
                self._settle_displaced(rec)
        self._sock: Optional[socketlib.socket] = None
        self._stop = threading.Event()
        self._acceptor: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # recovery: rebind + resume
    # ------------------------------------------------------------------
    def _resume(self, rec: JobRecord) -> None:
        """Rebind one recovered job to its journaled device and restart
        it — mid-segment from the checkpointed carry, otherwise at the
        next unfinished iteration."""
        if rec.workload is None:
            # admitted state is restored (it still charges admission),
            # but a closure-based body cannot be reconstructed
            self.recovery.setdefault("unresumable", []).append(rec.name)
            return
        resume = rec.carry
        remaining = rec.n_iterations - rec.done_iterations
        if remaining <= 0 and resume is None:
            return
        remaining = max(remaining, 1)
        prof = JobProfile.from_dict(rec.profile)
        body = make_body(self.cluster, rec.name, rec.workload,
                         store=self.store,
                         checkpoint_every=self.checkpoint_every,
                         offset=rec.done_iterations, resume=resume)
        job = RTJob(rec.name, body, period_s=prof.period_ms / 1e3,
                    priority=prof.priority,
                    deadline_s=(prof.deadline_ms or prof.period_ms) / 1e3,
                    best_effort=prof.best_effort,
                    n_iterations=remaining, device=rec.device)
        # NOT re-submitted: its admission already charges the rebuilt
        # controller (rebuild re-admitted it) — bind_job honors the
        # journaled immutable binding and bypasses a double admission
        self.cluster.bind_job(job, rec.device)
        job.start(self.cluster)
        self.recovery["resumed"][rec.name] = {
            "device": rec.device,
            "iteration": (resume["iteration"] if resume
                          else rec.done_iterations),
            "slice": resume["slice"] if resume else 0,
            "remaining_iterations": remaining}

    def _settle_displaced(self, rec: JobRecord) -> None:
        """Settle one displaced-ledger entry left by a crash that
        interrupted a fail-over: re-submit the job through the normal
        admit→place→bind path (which journals the outcome, clearing
        the ledger), or journal an explicit refusal when the body
        cannot be reconstructed."""
        prof = JobProfile.from_dict(rec.profile)
        outcome = self.recovery.setdefault("displaced_settled", {})
        if rec.workload is None:
            self.store.record_decision(
                prof, AdmissionDecision.refuse(
                    "validation-refused",
                    error="displaced by device failure; closure-based "
                          "body not reconstructible").bound(None, None),
                device=None, epoch=self.cluster.epoch or None)
            outcome[rec.name] = "refused (unresumable)"
            return
        remaining = max(rec.n_iterations - rec.done_iterations, 1)
        body = make_body(self.cluster, rec.name, rec.workload,
                         store=self.store,
                         checkpoint_every=self.checkpoint_every,
                         offset=rec.done_iterations, resume=rec.carry)
        dec = self.cluster._submit(
            prof, None, body, strategy="least_loaded",
            n_iterations=remaining, start=True,
            journal_meta={"workload": rec.workload})
        outcome[rec.name] = ("rebound to device "
                             f"{dec.get('device')}"
                             if dec["admitted"] else
                             f"refused ({dec.get('error') or dec.reason})")

    # ------------------------------------------------------------------
    # request handling (directly callable — tests drive it in-process)
    # ------------------------------------------------------------------
    def handle(self, req: Mapping) -> dict:
        op = req.get("op")
        if op == "ping":
            return {"ok": True, "pid": os.getpid(),
                    "socket": self.socket_path}
        if op == "submit":
            rid = req.get("request_id")
            if rid is not None and rid in self._requests:
                # idempotent resubmission (client retry across a
                # restart/transport failure): return the journaled
                # decision — the job was NOT admitted twice
                prev = self._requests[rid]
                out = dict(prev.get("decision") or prev)
                out["deduped"] = True
                return out
            prof = JobProfile.from_dict(req["profile"])
            try:
                spec = normalize_spec(req["workload"])
            except KeyError as e:
                return AdmissionDecision.refuse(
                    "validation-refused", error=str(e)).journal_form()
            n_iter = int(req.get("n_iterations", 1))
            body = make_body(self.cluster, prof.name, spec,
                             store=self.store,
                             checkpoint_every=self.checkpoint_every)
            dec = self.cluster._submit(
                prof, None, body, strategy=req.get("strategy"),
                n_iterations=n_iter, start=bool(req.get("start")),
                stop_after_s=req.get("stop_after_s"),
                journal_meta={"workload": spec, "request_id": rid})
            if rid is not None:
                self._requests[rid] = {"job": prof.name,
                                       "admitted": bool(dec["admitted"]),
                                       "decision": dec.journal_form()}
            return dec.journal_form()
        if op == "release":
            return self.cluster.release(req["name"])
        if op == "fail_device":
            return self.cluster.fail_device(
                int(req["device"]), reason=req.get("reason", ""))
        if op == "audit":
            st = self.store.load()
            return {"epoch": st.epoch,
                    "failed_devices": sorted(st.failed_devices),
                    "unaccounted": st.unaccounted(),
                    "shed": sorted(st.shed),
                    "live": sorted(st.jobs)}
        if op == "status":
            return {"pid": os.getpid(), "backend": "daemon",
                    "n_devices": self.cluster.n_devices,
                    "placement": self.cluster.placement,
                    "admitted": [p.name for p in
                                 self.cluster.admission.admitted],
                    "recovery": self.recovery,
                    "stats": self.cluster.stats()}
        if op == "jobs":
            return self._jobs_detail()
        if op == "per_device_mort":
            return self.cluster.per_device_mort()
        if op == "compact":
            st = self.store.compact()
            return {"jobs": sorted(st.jobs)}
        if op == "shutdown":
            # delay the flag so the handler thread can flush the
            # response before the process starts tearing down
            threading.Timer(0.2, self._stop.set).start()
            return {"ok": True}
        raise ValueError(f"unknown op {op!r}")

    def _jobs_detail(self) -> dict:
        """Per-job view joining the journal (admitted WCRT evidence) and
        the live RTJob stats — what the kill-and-recover suite compares
        MORT against."""
        out = {}
        for name, rec in self.store.load().jobs.items():
            job = self.cluster.find_job(name)
            stats = job.stats if job is not None else None
            out[name] = {
                "device": rec.device,
                "best_effort": rec.profile.get("best_effort", False),
                "wcrt_ms": rec.decision.get("wcrt", {}).get(name),
                "via": rec.decision.get("via"),
                "n_iterations": rec.n_iterations,
                "done_iterations": rec.done_iterations,
                "carry": rec.carry,
                "state": job.state if job is not None else None,
                "completions": stats.completions if stats else 0,
                "deadline_misses": (stats.deadline_misses
                                    if stats else 0),
                "mort_s": stats.mort if stats else None,
            }
        return out

    # ------------------------------------------------------------------
    # the socket server
    # ------------------------------------------------------------------
    def start(self) -> "SchedDaemon":
        if os.path.exists(self.socket_path):
            os.unlink(self.socket_path)   # stale socket of a killed daemon
        self._sock = socketlib.socket(socketlib.AF_UNIX,
                                      socketlib.SOCK_STREAM)
        self._sock.bind(self.socket_path)
        self._sock.listen(16)
        self._sock.settimeout(0.25)       # poll the stop flag
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          name="schedd-accept",
                                          daemon=True)
        self._acceptor.start()
        return self

    def _accept_loop(self) -> None:
        assert self._sock is not None
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socketlib.timeout:
                continue
            except OSError:
                break
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socketlib.socket) -> None:
        with conn:
            try:
                conn.settimeout(30.0)
                buf = b""
                while b"\n" not in buf:
                    chunk = conn.recv(65536)
                    if not chunk:
                        break
                    buf += chunk
                if not buf.strip():
                    return
                req = json.loads(buf.decode())
                resp = {"ok": True, "result": self.handle(req)}
            except Exception as e:  # noqa: BLE001 — protocol boundary
                resp = {"ok": False,
                        "error": f"{type(e).__name__}: {e}"}
            try:
                conn.sendall((json.dumps(resp, default=str)
                              + "\n").encode())
            except OSError:
                pass

    def _touch_heartbeat(self) -> None:
        if self.heartbeat_file is None:
            return
        try:
            tmp = self.heartbeat_file + ".tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                f.write(json.dumps({"pid": os.getpid(),
                                    "t": time.time()}))
            os.replace(tmp, self.heartbeat_file)
        except OSError:
            pass

    def serve_forever(self) -> None:
        if self._acceptor is None:
            self.start()
        self._touch_heartbeat()
        while not self._stop.is_set():
            self._stop.wait(0.25)
            self._touch_heartbeat()
        self.stop()

    def stop(self) -> None:
        self._stop.set()
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        if os.path.exists(self.socket_path):
            try:
                os.unlink(self.socket_path)
            except OSError:
                pass
        self.cluster.shutdown()
        self.store.close()

    def __enter__(self) -> "SchedDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.sched.daemon",
        description="durable scheduling daemon (journaled job store, "
                    "crash recovery, unix-socket submission API)")
    ap.add_argument("--store", required=True,
                    help="job store directory (journal + snapshots + "
                         "carries)")
    ap.add_argument("--socket", default=None,
                    help="unix socket path (default: <store>/sock)")
    ap.add_argument("--n-devices", type=int, default=1)
    ap.add_argument("--policy", default="ioctl")
    ap.add_argument("--wait-mode", default="suspend",
                    choices=("suspend", "busy"))
    ap.add_argument("--n-cpus", type=int, default=4)
    ap.add_argument("--epsilon-ms", type=float, default=1.0)
    ap.add_argument("--placement", default="pinned")
    ap.add_argument("--headroom", type=float, default=1.0)
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--no-conform", action="store_true",
                    help="skip the recovery decision-conformance assert "
                         "(debugging only)")
    ap.add_argument("--compact", action="store_true",
                    help="compact the journal into a snapshot on start")
    ap.add_argument("--health", action="store_true",
                    help="attach per-device health monitoring (slice "
                         "heartbeats, stall→suspect→failed ladder, "
                         "auto fail-over)")
    ap.add_argument("--health-stall-s", type=float, default=5.0,
                    help="stalled-slice seconds before a device turns "
                         "suspect")
    ap.add_argument("--health-fail-s", type=float, default=5.0,
                    help="additional suspect seconds before failed")
    ap.add_argument("--shed-at", type=float, default=None,
                    help="total device utilization above which best-"
                         "effort jobs are shed (enables the overload "
                         "degradation ladder)")
    ap.add_argument("--resume-at", type=float, default=None,
                    help="utilization under which shed jobs resume "
                         "(default: 0.8 * shed-at)")
    ap.add_argument("--tier-budget", action="append", default=[],
                    metavar="TIER=FRAC",
                    help="per-tier best-effort utilization budget "
                         "(repeatable, e.g. --tier-budget 0=0.2; "
                         "needs --shed-at)")
    ap.add_argument("--heartbeat-file", default=None,
                    help="liveness beacon touched every loop turn "
                         "(sched.supervisor watches its mtime)")
    ap.add_argument("--auto-compact-bytes", type=int, default=None,
                    help="auto-compact the journal past this size")
    args = ap.parse_args(argv)

    health = (HealthConfig(stall_timeout_s=args.health_stall_s,
                           fail_timeout_s=args.health_fail_s)
              if args.health else None)
    if args.tier_budget and args.shed_at is None:
        ap.error("--tier-budget needs --shed-at (the budgets refine "
                 "the overload ladder)")
    budgets = {int(t): float(b) for t, b in
               (spec.split("=", 1) for spec in args.tier_budget)} or None
    shed = (ShedPolicy(shed_at=args.shed_at,
                       resume_at=(args.resume_at
                                  if args.resume_at is not None
                                  else 0.8 * args.shed_at),
                       tier_budgets=budgets)
            if args.shed_at is not None else None)
    auto_compact = (CompactionPolicy(max_bytes=args.auto_compact_bytes)
                    if args.auto_compact_bytes is not None else None)
    daemon = SchedDaemon(
        args.store, args.socket, n_devices=args.n_devices,
        policy=args.policy, wait_mode=args.wait_mode, n_cpus=args.n_cpus,
        epsilon_ms=args.epsilon_ms, placement=args.placement,
        headroom=args.headroom, checkpoint_every=args.checkpoint_every,
        conform=not args.no_conform, health=health, shed_policy=shed,
        heartbeat_file=args.heartbeat_file, auto_compact=auto_compact)
    if args.compact:
        daemon.store.compact()
    daemon.start()
    print(f"schedd ready pid={os.getpid()} socket={daemon.socket_path} "
          f"recovered={daemon.recovery['recovered']} "
          f"resumed={sorted(daemon.recovery['resumed'])}", flush=True)

    def _term(signum, frame):
        daemon._stop.set()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    try:
        daemon.serve_forever()
    finally:
        daemon.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
