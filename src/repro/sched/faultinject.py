"""Deterministic fault injection for the scheduling runtime
(DESIGN.md §10).

The chaos suite must be able to make a specific device hang on a
specific slice of a specific job, make a slice raise, or SIGKILL the
daemon process mid-slice — deterministically, on any host, without
patching executor internals.  This module is that seam:

  * :class:`FaultSpec` — one planned fault: *where* (device / job /
    slice index match, each optional) and *what* (``hang`` for
    ``hang_s`` seconds inside the device lock, ``raise`` an
    :class:`InjectedFault` from the slice, ``kill`` the process with
    SIGKILL — no cleanup whatsoever, exactly like a machine check).
  * :class:`FaultInjector` — the plan holder.
    ``DeviceExecutor.run_sliced``/``run`` call :meth:`fire` at every
    dispatch; specs fire at most once unless ``once=False``.
  * ``from_env()`` — subprocess activation: ``REPRO_FAULT_PLAN`` holds
    either inline JSON or a path to a JSON file, so a *daemon under
    test* injects its own faults with no test hooks in the daemon code.

Injection is a no-op unless a plan is explicitly installed (constructor
argument or environment variable), so production paths pay one ``is
None`` check per dispatch.
"""
from __future__ import annotations

import json
import os
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import List, Mapping, Optional, Sequence, Union

ENV_PLAN = "REPRO_FAULT_PLAN"

KINDS = ("hang", "raise", "kill")


class InjectedFault(RuntimeError):
    """The exception a ``raise``-kind spec throws from inside a slice —
    deliberately a *generic* runtime error (not ``FaultContained``), so
    it exercises the same containment path a real kernel failure
    would."""


@dataclass
class FaultSpec:
    """One planned fault.  ``device``/``job``/``slice_idx`` are match
    filters (``None`` matches anything); ``after_matches`` skips the
    first N matching dispatches before firing."""
    kind: str
    device: Optional[int] = None
    job: Optional[str] = None
    slice_idx: Optional[int] = None
    after_matches: int = 0
    hang_s: float = 0.0
    once: bool = True
    fired: int = field(default=0, repr=False)
    _seen: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(available: {KINDS})")

    def matches(self, device: int, job: str, slice_idx: int) -> bool:
        if self.once and self.fired:
            return False
        if self.device is not None and device != self.device:
            return False
        if self.job is not None and job != self.job:
            return False
        if self.slice_idx is not None and slice_idx != self.slice_idx:
            return False
        if self._seen < self.after_matches:
            self._seen += 1
            return False
        return True

    @classmethod
    def from_dict(cls, d: Mapping) -> "FaultSpec":
        return cls(**{k: v for k, v in dict(d).items()
                      if not k.startswith("_") and k != "fired"})


class FaultInjector:
    """Holds the plan; executors call :meth:`fire` at every dispatch."""

    def __init__(self, specs: Sequence[Union[FaultSpec, Mapping]] = ()):
        self.specs: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec.from_dict(s)
            for s in specs]
        self.log: List[dict] = []          # every fired fault, audited
        self._lock = threading.Lock()

    def add(self, spec: Union[FaultSpec, Mapping]) -> "FaultInjector":
        with self._lock:
            self.specs.append(spec if isinstance(spec, FaultSpec)
                              else FaultSpec.from_dict(spec))
        return self

    def fire(self, *, device: int, job: str, slice_idx: int) -> None:
        """Called by the executor inside the device lock, immediately
        before the slice dispatch.  A ``hang`` sleeps here (the slice
        heartbeat stays armed, exactly like a hung kernel); a ``raise``
        throws :class:`InjectedFault`; a ``kill`` SIGKILLs the process
        — the journal's last fsync'd record is the recovery point."""
        with self._lock:
            hit = next((s for s in self.specs
                        if s.matches(device, job, slice_idx)), None)
            if hit is None:
                return
            hit.fired += 1
            self.log.append({"kind": hit.kind, "device": device,
                             "job": job, "slice": slice_idx,
                             "t": time.monotonic()})
        if hit.kind == "hang":
            time.sleep(hit.hang_s)
        elif hit.kind == "raise":
            raise InjectedFault(
                f"injected slice exception (device {device}, job "
                f"{job!r}, slice {slice_idx})")
        elif hit.kind == "kill":
            os.kill(os.getpid(), signal.SIGKILL)

    def fired(self, kind: Optional[str] = None) -> List[dict]:
        with self._lock:
            return [e for e in self.log
                    if kind is None or e["kind"] == kind]


def from_env(environ: Optional[Mapping] = None) -> Optional[FaultInjector]:
    """Build the process-wide injector from ``$REPRO_FAULT_PLAN``
    (inline JSON — a list of spec dicts — or a path to a JSON file);
    ``None`` when unset, which is the production fast path."""
    env = os.environ if environ is None else environ
    raw = env.get(ENV_PLAN)
    if not raw:
        return None
    raw = raw.strip()
    if raw.startswith("[") or raw.startswith("{"):
        plan = json.loads(raw)
    else:
        with open(raw, encoding="utf-8") as f:
            plan = json.load(f)
    if isinstance(plan, Mapping):
        plan = [plan]
    return FaultInjector(plan)
