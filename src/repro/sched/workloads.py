"""Reconstructible workloads: the registry behind durable submissions.

A daemon cannot journal a closure.  A durable submission therefore names
a *registered workload* — ``{"name": <registry key>, "kwargs": {...}}``
— and the registry maps that spec back to a fresh
:class:`~repro.core.segments.SlicedOp` factory on every release, on
every process: the daemon reconstructs the exact same job body after a
crash and resumes it from the journaled carry (DESIGN.md §9).

``make_body`` is the one definition of the durable job body: one sliced
device segment per release, every completed slice checkpointed through
``checkpointer.save_carry`` with the pointer journaled, iteration
completion journaled — so the store always knows the last durable point
of every live job.  Workload steps must be idempotent at slice
granularity: a crash between the last carry checkpoint and the
``iter_done`` record replays at most one slice + finalize.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Mapping, Optional, Union

import numpy as np

from ..core.segments import SlicedOp

WORKLOADS: Dict[str, Callable[..., SlicedOp]] = {}


def register_workload(name: str,
                      factory: Callable[..., SlicedOp]) -> None:
    """Register ``factory(**kwargs) -> SlicedOp`` under ``name``.  The
    factory must be importable in the daemon process (module-level), or
    recovery cannot rebuild the job."""
    WORKLOADS[name] = factory


def get_workload(name: str) -> Callable[..., SlicedOp]:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r} (registered: "
                       f"{sorted(WORKLOADS)})") from None


def normalize_spec(spec: Union[str, Mapping], *,
                   check: bool = True) -> dict:
    """``"demo.spin"`` or ``{"name": ..., "kwargs": {...}}`` → the
    canonical journal form.  ``check=False`` skips the registry
    lookup — a socket client must not validate against its *own*
    registry (the daemon's may register workloads the client process
    never imported); the daemon re-validates on receipt."""
    if isinstance(spec, str):
        spec = {"name": spec}
    out = {"name": spec["name"], "kwargs": dict(spec.get("kwargs") or {})}
    if check:
        get_workload(out["name"])  # fail fast on unknown names
    return out


def make_body(executor, job_name: str, spec: Mapping, *,
              store=None, checkpoint_every: int = 1, offset: int = 0,
              resume: Optional[Mapping] = None) -> Callable:
    """The durable RTJob body for a registered workload.

    Each release ``it`` runs one fresh ``SlicedOp`` from the registry
    under the executor's sliced dispatch (admission re-checked per
    slice).  Iteration indices are global across restarts: a recovered
    job is rebuilt with ``offset = journaled done_iterations`` and
    ``n_iterations = remaining``, so ``offset + it`` matches the journal.

    With a store attached, every ``checkpoint_every``-th slice snapshots
    the carry (``save_carry``, tmp-rename atomic) and journals the
    pointer; ``resume = {"iteration": i, "slice": s}`` makes the release
    whose global index is ``i`` restore the latest snapshot and start at
    its journaled slice (a ``resume`` audit record is appended — the
    kill-and-recover suite asserts on it)."""
    spec = normalize_spec(spec)
    factory = get_workload(spec["name"])
    kwargs = spec["kwargs"]

    def body(job, it):
        from . import checkpointer  # lazy: jax import
        g = offset + it
        op = factory(**kwargs)
        carry, start = None, 0
        if (resume is not None and store is not None
                and g == resume["iteration"]):
            restored = checkpointer.latest_carry(
                store.carry_dir(job_name), job_name, op.init())
            if restored is not None:
                start, carry = restored
                store.record_resume(job_name, g, start)
        ckpt = None
        if store is not None:
            def ckpt(i, c):
                checkpointer.save_carry(store.carry_dir(job_name),
                                        job_name, i, c)
                store.record_carry(job_name, g, i)
        with executor.device_segment(job):
            executor.run_sliced(
                job, op, carry=carry, start=start, checkpoint=ckpt,
                checkpoint_every=(checkpoint_every
                                  if store is not None else 0))
        if store is not None:
            store.record_iteration_done(job_name, g)

    return body


# --------------------------------------------------------------------------
# built-in demo workloads (the recovery suite's and CI smoke's subjects)
# --------------------------------------------------------------------------

def _spin(slices: int = 8, slice_ms: float = 25.0) -> SlicedOp:
    """Pure host-timed sliced segment: each slice sleeps ``slice_ms``
    and bumps a counter carry — the minimal checkpointable RT job (the
    counter proves where a resumed run actually restarted)."""
    def init():
        return {"done": np.zeros((), np.int64)}

    def step(carry, i):
        time.sleep(slice_ms / 1e3)
        return {"done": carry["done"] + 1}

    def finalize(carry):
        return carry["done"]

    return SlicedOp(slices, init, step, finalize, label="demo.spin")


def _count(total: int = 64, per_slice: int = 8) -> SlicedOp:
    """Device-arithmetic sliced segment: accumulates ``total`` integers
    ``per_slice`` at a time (resume-exact: the carry holds the running
    sum and the final value is checkable as total*(total+1)/2)."""
    def init():
        return {"sum": np.zeros((), np.int64)}

    def step(carry, i):
        lo = i * per_slice
        hi = min((i + 1) * per_slice, total)
        return {"sum": carry["sum"] + sum(range(lo + 1, hi + 1))}

    def finalize(carry):
        return carry["sum"]

    from ..core.segments import n_slices_for
    return SlicedOp(n_slices_for(total, per_slice), init, step, finalize,
                    label="demo.count")


register_workload("demo.spin", _spin)
register_workload("demo.count", _count)
