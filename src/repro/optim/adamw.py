"""AdamW with global-norm clipping, cosine schedule, and ZeRO-1-friendly
fp32 moments (their sharding is decided by the launch layer via
``zero1_pspecs`` — the optimizer itself is sharding-agnostic)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, Dict[str, Any]]:
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step)
        vhat = v / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step,
                        }, {"lr": lr, "grad_norm": gnorm}


def adamw_step(cfg: AdamWConfig, grads, opt_state, params):
    new_params, new_state, metrics = adamw_update(cfg, grads, opt_state,
                                                  params)
    return new_params, new_state, metrics
