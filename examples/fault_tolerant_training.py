"""Fault tolerance + elasticity: training survives injected node failures
(restart-from-checkpoint) and the state re-shards onto a different mesh.

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import numpy as np

from repro.configs import get
from repro.launch.train import train
from repro.sched import latest_step, restore


def main() -> None:
    cfg = get("smollm-135m").reduced()
    ckpt = tempfile.mkdtemp(prefix="repro_ft_")

    # inject a failure at step 12; the loop rolls back to the newest
    # checkpoint and replays
    state, losses, stats = train(cfg, n_steps=25, global_batch=8,
                                 seq_len=64, ckpt_dir=ckpt, save_every=5,
                                 log_every=0, fail_at=12)
    print(f"failures={stats.failures} restarts={stats.restarts} "
          f"replayed={stats.replayed_steps}")
    assert stats.restarts == 1
    print(f"final checkpoint step: {latest_step(ckpt)}")

    # restore elsewhere (e.g. a rescaled mesh would pass shardings=...)
    back = restore(ckpt, state)
    for a, b in zip(np.asarray(state["params"]["embed"], np.float32).ravel(),
                    np.asarray(back["params"]["embed"], np.float32).ravel()):
        assert a == b
        break
    print("fault_tolerant_training OK")


if __name__ == "__main__":
    main()
