"""The paper's contribution end-to-end, on the sliced-segment API: a
latency-critical inference job preempts a best-effort training job on the
shared device with *bounded* delay — both jobs expose their device work as
sliced GPU-access segments (`repro.core.segments`), so a preemption waits
out at most one in-flight slice instead of a whole program, and the
admission test's epsilon comes from the *measured* per-slice profile
rather than a whole-train-step worst case.

  PYTHONPATH=src python examples/preemptive_serving.py

With ``--n-devices N`` (N > 1) the same two workloads run on a
ClusterExecutor: inference pinned to device 0, training pinned to device
N-1 (the boundary device — the admission path the cross-device analysis
guards), so the inference WCRT is computed on the multi-device platform
and the per-device MORTs show the isolation.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core.segments import SegmentedWorkload, SlicedOp
from repro.launch.serve import InferenceEngine
from repro.launch.steps import build_train_step
from repro.models import transformer
from repro.optim import adamw
from repro.sched import JobProfile, RTJob, connect


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-devices", type=int, default=1,
                    help="N>1: train on device N-1, infer on device 0")
    args = ap.parse_args()
    n_devices = args.n_devices
    infer_dev, train_dev = 0, n_devices - 1
    # physical placement: pin each workload's arrays (and therefore its
    # XLA programs) to its scheduling device when the host exposes that
    # many jax devices; otherwise the scheduling isolation still holds
    # but the programs share one physical device (warn — the analysis
    # models N devices)
    jdevs = jax.devices()
    if n_devices > 1 and len(jdevs) < n_devices:
        print(f"WARNING: --n-devices {n_devices} but only {len(jdevs)} "
              f"jax device(s); programs share one physical device "
              f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{n_devices})")
    infer_jdev = jdevs[infer_dev] if len(jdevs) > infer_dev else None
    train_jdev = jdevs[train_dev] if len(jdevs) > train_dev else None
    # --- workloads -----------------------------------------------------
    infer_cfg = get("smollm-135m").reduced()
    train_cfg = get("olmo-1b").reduced()
    engine = InferenceEngine(infer_cfg, max_len=64, device=infer_jdev)
    params = transformer.init_params(train_cfg, jax.random.PRNGKey(0))
    if train_jdev is not None:
        params = jax.device_put(params, train_jdev)
    state = {"params": params, "opt": adamw.init_opt_state(params)}
    step_fn = jax.jit(build_train_step(train_cfg))
    microbatches = [
        {"inputs": jnp.zeros((1, 32), jnp.int32),
         "labels": jnp.zeros((1, 32), jnp.int32)} for _ in range(2)]
    if train_jdev is not None:
        microbatches = jax.device_put(microbatches, train_jdev)

    # --- the job bodies as segmented workloads ---------------------------
    # inference: one prefill slice + 4 decode-token slices per release
    prompt = jnp.zeros((2, 8), jnp.int32)
    infer_wl = (SegmentedWorkload("infer")
                .device(lambda: engine.prefill_segment(prompt))
                .device(lambda: engine.decode_segment(4)))

    def train_op() -> SlicedOp:
        """One best-effort training release: each slice is a full train
        step on one microbatch (the bounded-duration dispatch that keeps
        the device preemptible), state committed at finalize."""
        def step(carry, i):
            p, o, _ = step_fn(carry[0], carry[1], microbatches[i])
            return (p, o)

        def finalize(carry):
            state.update(params=carry[0], opt=carry[1])
            return carry[1]

        return SlicedOp(len(microbatches),
                        lambda: (state["params"], state["opt"]),
                        step, finalize, label="train_step")

    train_wl = SegmentedWorkload("train").device(train_op)

    # --- measured slice profiles -> admission control --------------------
    # (the first profile rep doubles as the jit warm-up)
    infer_prof = infer_wl.profile(reps=2)
    train_prof = train_wl.profile(reps=2)
    # epsilon = admission-update cost + the residual of one in-flight
    # *slice* (any job's): preemption takes effect at slice boundaries,
    # so the bound is one slice — not the whole train step the pre-sliced
    # API had to assume (DESIGN.md §6)
    max_slice = max(infer_prof.max_slice_ms, train_prof.max_slice_ms)
    eps_ms = 1.0 + max_slice * 1.2

    # --- the cluster: admit→place→bind, then run preemptively ------------
    # (through the unified facade: connect() owns an in-process cluster;
    # the same submit() would reach a daemon given a socket path)
    client = connect(n_devices=n_devices, policy="notify",
                     wait_mode="suspend", n_cpus=1, epsilon_ms=eps_ms)
    cluster = client.cluster
    res = client.submit(
        JobProfile.from_workload(infer_prof, period_ms=1500, priority=50,
                                 margin=2.0, device=infer_dev),
        workload=infer_wl, n_iterations=100)
    print(f"inference admitted={res['admitted']} on device "
          f"{res['device']} WCRT={res['wcrt'].get('infer', 0):.1f}ms "
          f"(slices {[round(s, 1) for s in infer_prof.device[1].slice_ms]}"
          f"ms, max slice {max_slice:.1f}ms, epsilon {eps_ms:.0f}ms)")
    res_train = client.submit(
        JobProfile.from_workload(train_prof, period_ms=500, priority=0,
                                 best_effort=True, margin=1.5,
                                 device=train_dev),
        workload=train_wl, n_iterations=100)
    if res["job"] is None or res_train["job"] is None:
        # report the refusal instead of crashing on job=None — nothing
        # has started yet (submit was called without start=True)
        client.close(shutdown=True)
        refused = res if res["job"] is None else res_train
        why = refused.get("error") or refused["wcrt"]
        raise SystemExit(f"admission refused: {why}")
    infer: RTJob = res["job"]
    train: RTJob = res_train["job"]
    train.start(cluster, stop_after_s=6.0)
    time.sleep(0.05)
    infer.start(cluster, stop_after_s=6.0)
    infer.join(30)
    train.join(30)
    client.close(shutdown=True)
    cluster.assert_migration_free()

    wcrt = res["wcrt"].get("infer", float("inf"))
    mort_ms = (infer.stats.mort or 0.0) * 1e3
    obs_slice = (max(infer.stats.max_slice_time or 0.0,
                     train.stats.max_slice_time or 0.0)) * 1e3
    print(f"inference: {infer.stats.completions} jobs, "
          f"MORT {mort_ms:.1f}ms vs WCRT {wcrt:.1f}ms, "
          f"misses {infer.stats.deadline_misses}")
    print(f"training (best-effort): {train.stats.completions} releases "
          f"alongside; longest observed slice {obs_slice:.1f}ms "
          f"(protective bound {eps_ms:.0f}ms)")
    if n_devices > 1:
        morts = {d: (round(v * 1e3, 1) if v is not None else None)
                 for d, v in client.per_device_mort().items()}
        print(f"per-device MORT (ms): {morts} "
              f"(infer on {infer_dev}, train on {train_dev})")
    assert infer.stats.completions > 0, "inference never completed"
    assert mort_ms <= wcrt + 1e-6, "WCRT bound violated!"
    print("preemptive_serving OK")


if __name__ == "__main__":
    main()
