"""The paper's contribution end-to-end: a latency-critical inference job
preempts a best-effort training job on the shared device, with admission
control guaranteeing the inference job's response-time bound.

  PYTHONPATH=src python examples/preemptive_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.launch.serve import InferenceEngine
from repro.launch.steps import build_train_step
from repro.models import transformer
from repro.optim import adamw
from repro.sched import AdmissionController, DeviceExecutor, JobProfile, RTJob


def main() -> None:
    # --- workloads -----------------------------------------------------
    infer_cfg = get("smollm-135m").reduced()
    train_cfg = get("olmo-1b").reduced()
    engine = InferenceEngine(infer_cfg, max_len=64)
    params = transformer.init_params(train_cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_opt_state(params)}
    step_fn = jax.jit(build_train_step(train_cfg))
    batch = {"inputs": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.zeros((2, 32), jnp.int32)}

    def warm():
        prompt = jnp.zeros((2, 8), jnp.int32)
        engine.prefill_batch(prompt)
        engine.decode_chunk(2)
        p, o, _ = step_fn(state["params"], state["opt"], batch)

    warm()

    # --- profile + admission control ------------------------------------
    t0 = time.perf_counter()
    engine.prefill_batch(jnp.zeros((2, 8), jnp.int32))
    jax.block_until_ready(engine.decode_chunk(4))
    infer_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    jax.block_until_ready(step_fn(state["params"], state["opt"], batch))
    train_ms = (time.perf_counter() - t0) * 1e3

    # epsilon = admission-update cost + the residual of an in-flight device
    # program: preemption takes effect at program boundaries, so the
    # longest single program (the train step) bounds the wait — the TPU
    # analogue of the paper's thread-block preemption delay (DESIGN.md §2)
    eps_ms = train_ms * 1.2 + 1.0
    ac = AdmissionController(mode="notify", wait_mode="suspend", n_cpus=1,
                             epsilon_ms=eps_ms)
    res = ac.try_admit(JobProfile(
        "infer", [2, 1], [(1.0, infer_ms * 2.0)], period_ms=1500,
        priority=50))
    print(f"inference admitted={res['admitted']} "
          f"WCRT={res['wcrt'].get('infer', 0):.1f}ms "
          f"(segment {infer_ms:.1f}ms, epsilon {eps_ms:.0f}ms)")
    ac.try_admit(JobProfile("train", [2], [(1.0, train_ms * 1.5)],
                            period_ms=500, priority=0, best_effort=True))

    # --- run under the preemptive executor -------------------------------
    ex = DeviceExecutor(mode="notify", wait_mode="suspend")

    def infer_body(job, it):
        with ex.device_segment(job):
            ex.run(job, engine.prefill_batch, jnp.zeros((2, 8), jnp.int32))
            ex.run(job, engine.decode_chunk, 4)

    def train_body(job, it):
        with ex.device_segment(job):
            p, o, _ = ex.run(job, step_fn, state["params"], state["opt"],
                             batch)
            state.update(params=p, opt=o)

    infer = RTJob("infer", infer_body, period_s=1.5, priority=50,
                  n_iterations=100)
    train = RTJob("train", train_body, period_s=0.5, priority=0,
                  best_effort=True, n_iterations=100)
    train.start(ex, stop_after_s=6.0)
    infer.start(ex, stop_after_s=6.0)
    infer.join(30)
    train.join(30)
    ex.shutdown()

    wcrt = res["wcrt"].get("infer", float("inf"))
    print(f"inference: {infer.stats.completions} jobs, "
          f"MORT {infer.stats.mort * 1e3:.1f}ms vs WCRT {wcrt:.1f}ms, "
          f"misses {infer.stats.deadline_misses}")
    print(f"training (best-effort): {train.stats.completions} steps "
          f"completed alongside")
    assert infer.stats.mort * 1e3 <= wcrt + 1e-6, "WCRT bound violated!"
    print("preemptive_serving OK")


if __name__ == "__main__":
    main()
