"""The paper's contribution end-to-end, on the sliced-segment API: a
latency-critical inference job preempts a best-effort training job on the
shared device with *bounded* delay — both jobs expose their device work as
sliced GPU-access segments (`repro.core.segments`), so a preemption waits
out at most one in-flight slice instead of a whole program, and the
admission test's epsilon comes from the *measured* per-slice profile
rather than a whole-train-step worst case.

  PYTHONPATH=src python examples/preemptive_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core.segments import SegmentedWorkload, SlicedOp
from repro.launch.serve import InferenceEngine
from repro.launch.steps import build_train_step
from repro.models import transformer
from repro.optim import adamw
from repro.sched import AdmissionController, DeviceExecutor, JobProfile, RTJob


def main() -> None:
    # --- workloads -----------------------------------------------------
    infer_cfg = get("smollm-135m").reduced()
    train_cfg = get("olmo-1b").reduced()
    engine = InferenceEngine(infer_cfg, max_len=64)
    params = transformer.init_params(train_cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_opt_state(params)}
    step_fn = jax.jit(build_train_step(train_cfg))
    microbatches = [
        {"inputs": jnp.zeros((1, 32), jnp.int32),
         "labels": jnp.zeros((1, 32), jnp.int32)} for _ in range(2)]

    # --- the job bodies as segmented workloads ---------------------------
    # inference: one prefill slice + 4 decode-token slices per release
    prompt = jnp.zeros((2, 8), jnp.int32)
    infer_wl = (SegmentedWorkload("infer")
                .device(lambda: engine.prefill_segment(prompt))
                .device(lambda: engine.decode_segment(4)))

    def train_op() -> SlicedOp:
        """One best-effort training release: each slice is a full train
        step on one microbatch (the bounded-duration dispatch that keeps
        the device preemptible), state committed at finalize."""
        def step(carry, i):
            p, o, _ = step_fn(carry[0], carry[1], microbatches[i])
            return (p, o)

        def finalize(carry):
            state.update(params=carry[0], opt=carry[1])
            return carry[1]

        return SlicedOp(len(microbatches),
                        lambda: (state["params"], state["opt"]),
                        step, finalize, label="train_step")

    train_wl = SegmentedWorkload("train").device(train_op)

    # --- measured slice profiles -> admission control --------------------
    # (the first profile rep doubles as the jit warm-up)
    infer_prof = infer_wl.profile(reps=2)
    train_prof = train_wl.profile(reps=2)
    # epsilon = admission-update cost + the residual of one in-flight
    # *slice* (any job's): preemption takes effect at slice boundaries,
    # so the bound is one slice — not the whole train step the pre-sliced
    # API had to assume (DESIGN.md §6)
    max_slice = max(infer_prof.max_slice_ms, train_prof.max_slice_ms)
    eps_ms = 1.0 + max_slice * 1.2
    ac = AdmissionController(mode="notify", wait_mode="suspend", n_cpus=1,
                             epsilon_ms=eps_ms)
    res = ac.try_admit(JobProfile.from_workload(
        infer_prof, period_ms=1500, priority=50, margin=2.0))
    print(f"inference admitted={res['admitted']} "
          f"WCRT={res['wcrt'].get('infer', 0):.1f}ms "
          f"(slices {[round(s, 1) for s in infer_prof.device[1].slice_ms]}"
          f"ms, max slice {max_slice:.1f}ms, epsilon {eps_ms:.0f}ms)")
    ac.try_admit(JobProfile.from_workload(
        train_prof, period_ms=500, priority=0, best_effort=True,
        margin=1.5))

    # --- run under the preemptive executor -------------------------------
    ex = DeviceExecutor(mode="notify", wait_mode="suspend")
    infer = RTJob("infer", infer_wl.bind(ex), period_s=1.5, priority=50,
                  n_iterations=100)
    train = RTJob("train", train_wl.bind(ex), period_s=0.5, priority=0,
                  best_effort=True, n_iterations=100)
    train.start(ex, stop_after_s=6.0)
    time.sleep(0.05)
    infer.start(ex, stop_after_s=6.0)
    infer.join(30)
    train.join(30)
    ex.shutdown()

    wcrt = res["wcrt"].get("infer", float("inf"))
    mort_ms = (infer.stats.mort or 0.0) * 1e3
    obs_slice = (max(infer.stats.max_slice_time or 0.0,
                     train.stats.max_slice_time or 0.0)) * 1e3
    print(f"inference: {infer.stats.completions} jobs, "
          f"MORT {mort_ms:.1f}ms vs WCRT {wcrt:.1f}ms, "
          f"misses {infer.stats.deadline_misses}")
    print(f"training (best-effort): {train.stats.completions} releases "
          f"alongside; longest observed slice {obs_slice:.1f}ms "
          f"(protective bound {eps_ms:.0f}ms)")
    assert infer.stats.completions > 0, "inference never completed"
    assert mort_ms <= wcrt + 1e-6, "WCRT bound violated!"
    print("preemptive_serving OK")


if __name__ == "__main__":
    main()
