"""Quickstart: train a reduced-config model, checkpoint it, serve it.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get
from repro.launch.serve import InferenceEngine
from repro.launch.train import train

ARCH = "olmo-1b"          # any of repro.configs.names()
STEPS = 30


def main() -> None:
    entry = get(ARCH)
    cfg = entry.reduced()  # CPU-runnable config of the same family
    print(f"arch={ARCH} ({entry.family}); reduced config: "
          f"{cfg.n_layers}L d={cfg.d_model}")

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    state, losses, fault_stats = train(
        cfg, n_steps=STEPS, global_batch=8, seq_len=64, ckpt_dir=ckpt,
        save_every=10, log_every=10)
    print(f"loss: {losses[0]:.3f} -> {min(losses):.3f} (min) over "
          f"{STEPS} steps")
    assert losses[-1] == losses[-1], "loss is NaN"

    engine = InferenceEngine(cfg, params=state["params"], max_len=96)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    engine.prefill_batch(prompt)
    tokens = engine.decode_chunk(12)
    print("generated:", np.asarray(tokens[0]))
    print("quickstart OK")


if __name__ == "__main__":
    main()
