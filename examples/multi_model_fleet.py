"""A heterogeneous serving fleet on the preemptive cluster runtime: N
models from the config registry served together as a mixed-criticality
workload (``repro.launch.fleet``, DESIGN.md §12).

Interactive decode models run as RT jobs — admission prices their
measured per-slice profiles with the paper's RTA and refuses the fleet
rather than over-promise — while background training / batch-eval runs
best-effort underneath, shed first under overload and never able to
block an RT dispatch.  The per-model / per-tier stats surface
(``ClusterExecutor.stats()``) reports MORT, deadline misses and
nearest-rank p50/p99 per model and per criticality tier.

  PYTHONPATH=src python examples/multi_model_fleet.py --n-devices 2 \
      --models chat,assist,train

On a CPU host expose the devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
import argparse

from repro.launch.fleet import (check_fleet_report, default_fleet,
                                launch_fleet)
from repro.sched.elastic import ShedPolicy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-devices", type=int, default=2)
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--models", default="chat,assist,train",
                    help="comma-separated subset of the reference fleet")
    args = ap.parse_args()

    members = default_fleet(args.n_devices, args.models.split(","))
    # shed best-effort members above 85% device utilization, resuming
    # below 65%, with bulk (tier-0) background capped at a 30% share
    shed = ShedPolicy(shed_at=0.85, resume_at=0.65,
                      tier_budgets={0: 0.30})
    report = launch_fleet(members, n_devices=args.n_devices,
                          duration_s=args.duration, shed_policy=shed)

    for name, m in report["models"].items():
        s = report["per_model"].get(name, {})
        bound = ("best-effort" if m["best_effort"]
                 else f"WCRT {m['wcrt_ms']:.1f}ms")
        mort = (f"{s['mort_ms']:.1f}ms" if s.get("mort_ms") is not None
                else "-")
        print(f"{name} ({m['arch']}): tier {m['tier']}, device "
              f"{m['device']}, {bound}, completions "
              f"{s.get('completions', 0)}, MORT {mort}, misses "
              f"{s.get('deadline_misses', 0)}")
    for tier in sorted(report["per_tier"], reverse=True):
        t = report["per_tier"][tier]
        p99 = f"{t['p99_ms']:.1f}ms" if t["p99_ms"] is not None else "-"
        print(f"tier {tier}: {t['jobs']} — completions "
              f"{t['completions']}, misses {t['deadline_misses']}, "
              f"p99 {p99}")

    # the acceptance assertions: every RT model completed releases with
    # MORT within its admitted WCRT
    check_fleet_report(report)
    print("multi_model_fleet OK")


if __name__ == "__main__":
    main()
